"""Deterministic data pipeline: synthetic LM stream + memmap token files.

Production shape: each host reads only its shard of the global batch
(``host_slice``), shuffling is a stateless bijective permutation of the
sample index space (restart-safe: the step counter *is* the data state —
checkpoint restore resumes the stream exactly), and a background prefetch
thread keeps ``prefetch`` batches ready.  The prefetch queue is guarded by
the paper's own LibASL mutex (consumer = latency-critical big-core path,
refills reorder behind it).
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np

from repro.core.libasl import LibASL


def _mix64(x: np.ndarray) -> np.ndarray:
    """SplitMix64 — stateless bijection used as the shuffle."""
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    x = ((x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) \
        & np.uint64(0xFFFFFFFFFFFFFFFF)
    x = ((x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) \
        & np.uint64(0xFFFFFFFFFFFFFFFF)
    return x ^ (x >> np.uint64(31))


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    host_index: int = 0
    host_count: int = 1
    seed: int = 0
    token_file: str | None = None   # memmap int32 token file; synthetic if None


class TokenDataset:
    """Batch source: ``batch(step) -> {"tokens", "labels"}`` (host shard)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.host_count == 0
        self.host_batch = cfg.global_batch // cfg.host_count
        self._tokens = None
        if cfg.token_file:
            self._tokens = np.memmap(cfg.token_file, dtype=np.int32,
                                     mode="r")
            self._n_seqs = len(self._tokens) // (cfg.seq_len + 1)

    def batch(self, step: int) -> dict:
        c = self.cfg
        idx = (np.arange(self.host_batch, dtype=np.uint64)
               + np.uint64(step) * np.uint64(c.global_batch)
               + np.uint64(self.host_batch * c.host_index))
        mixed = _mix64(idx + np.uint64(c.seed) * np.uint64(0x1000003))
        if self._tokens is not None:
            rows = (mixed % np.uint64(self._n_seqs)).astype(np.int64)
            chunk = np.stack([
                self._tokens[r * (c.seq_len + 1):(r + 1) * (c.seq_len + 1)]
                for r in rows])
        else:
            # Synthetic: a learnable Markov-ish stream (next = f(prev)),
            # so smoke training shows a real loss decrease.  Noise derives
            # per-(sample, position) from the bijective mix, so host shards
            # tile the global batch exactly (restart- and topology-safe).
            pos = _mix64(np.arange(c.seq_len, dtype=np.uint64)
                         + np.uint64(0xABCDEF))
            tmix = _mix64(mixed[:, None] ^ pos[None, :])
            noise = (tmix % np.uint64(7)).astype(np.int64)
            start = (mixed % np.uint64(c.vocab)).astype(np.int64)
            chunk = np.empty((self.host_batch, c.seq_len + 1), np.int64)
            chunk[:, 0] = start
            for t in range(c.seq_len):
                chunk[:, t + 1] = (chunk[:, t] * 31 + 17 + noise[:, t]) \
                    % c.vocab
        return {"tokens": chunk[:, :-1].astype(np.int32),
                "labels": chunk[:, 1:].astype(np.int32)}


class PrefetchLoader:
    """Background-thread prefetcher over a TokenDataset."""

    def __init__(self, ds: TokenDataset, start_step: int = 0,
                 prefetch: int = 2):
        self.ds = ds
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._asl = LibASL(is_big_core=lambda: not _is_producer())
        self._lock = self._asl.mutex()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        _PRODUCER.flag = True
        step = self._step
        while not self._stop.is_set():
            b = self.ds.batch(step)
            try:
                self._q.put((step, b), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        with self._lock:
            return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=1.0)


_PRODUCER = threading.local()


def _is_producer() -> bool:
    return getattr(_PRODUCER, "flag", False)
