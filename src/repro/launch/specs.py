"""ShapeDtypeStruct input stand-ins + shardings for every (arch x shape) cell.

``input_specs`` returns abstract inputs for the step function of a cell —
weak-type-correct, shardable, zero device allocation — plus the matching
logical-axis trees used to derive in_shardings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig, ShapeSpec


def batch_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Abstract batch dict + logical axes per entry (train/prefill)."""
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.frontend == "audio_stub":
        specs = {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        axes = {"frames": ("batch", "seq", "act_embed"),
                "labels": ("batch", "seq")}
    elif cfg.frontend == "vision_stub":
        p = cfg.n_patches
        specs = {"tokens": jax.ShapeDtypeStruct((b, s - p), jnp.int32),
                 "patch_embeds": jax.ShapeDtypeStruct((b, p, cfg.d_model), f32),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        axes = {"tokens": ("batch", "seq"),
                "patch_embeds": ("batch", "seq", "act_embed"),
                "labels": ("batch", "seq")}
    else:
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if shape.kind == "prefill":
        specs.pop("labels")
        axes.pop("labels")
    return specs, axes


def decode_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Abstract (tokens, lengths, cache) for a decode cell."""
    b, t = shape.global_batch, shape.seq_len
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    lengths = jax.ShapeDtypeStruct((b,), jnp.int32)
    cache = lm.abstract_cache(cfg, b, t)
    axes = {"tokens": ("batch", "seq"), "lengths": ("batch",)}
    return tokens, lengths, cache, axes


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """The public entry: dict of abstract inputs for the cell's step fn."""
    if shape.kind == "decode":
        tokens, lengths, cache, _ = decode_specs(cfg, shape)
        return {"tokens": tokens, "lengths": lengths, "cache": cache}
    specs, _ = batch_specs(cfg, shape)
    return specs
