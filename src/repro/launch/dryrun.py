import sys

# Virtual host devices must be configured before the first jax import.
# Default 512 = 2 pods x 256 chips; ``--devices N`` scales it down so a
# CPU container can run the same path end-to-end (e.g. --devices 8).
from repro.launch.xla_flags import argv_device_count, ensure_host_devices

ensure_host_devices(argv_device_count(sys.argv, 512))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: placeholder
host devices stand in for the real chips.  Per cell we record
``memory_analysis`` (fits / doesn't), ``cost_analysis`` (FLOPs, bytes) and
the collective schedule summary into ``artifacts/dryrun/<cell>.json``
(incremental: cells already on disk are skipped unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
  # CPU-container end-to-end (8 virtual devices, tiny config, small batch):
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b \
      --shape train_4k --devices 8 --mesh 4x2 --tiny
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.dist.hlo_analysis import (analytic_model_flops, collective_stats,
                                     xla_cost)
from repro.dist.sharding import build_rules, use_mesh
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.launch.specs import batch_specs, decode_specs
from repro.models import lm
from repro.models.config import cell_applicable, standard_shapes
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.step import make_train_step

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(cfg, meta, shape, mesh):
    """-> (jitted fn, abstract args tuple) for one cell."""
    rules = build_rules(mesh, kv_heads=cfg.n_kv_heads,
                        n_experts=cfg.n_experts, step=shape.kind,
                        seq_parallel=cfg.seq_parallel,
                        expert_parallel=cfg.expert_parallel)
    aparams = lm.abstract_params(cfg)
    pspecs = lm.param_pspecs(cfg, rules)

    if shape.kind == "train":
        opt = AdamW(state_dtype=cfg.opt_state_dtype)
        lr_fn = cosine_schedule(3e-4, 100, 10000)
        step_fn = make_train_step(cfg, opt, lr_fn,
                                  microbatches=shape.microbatches)
        aopt = jax.eval_shape(opt.init, aparams)
        ospecs = type(aopt)(m=pspecs, v=pspecs, count=P())
        bspecs, baxes = batch_specs(cfg, shape)
        bshard = {k: rules.spec(baxes[k], bspecs[k].shape) for k in baxes}
        astep = jax.ShapeDtypeStruct((), jax.numpy.int32)
        in_sh = (_ns(mesh, pspecs), _ns(mesh, ospecs),
                 NamedSharding(mesh, P()), _ns(mesh, bshard))
        out_sh = (_ns(mesh, pspecs), _ns(mesh, ospecs),
                  NamedSharding(mesh, P()), None)
        fn = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))
        return rules, fn, (aparams, aopt, astep, bspecs)

    if shape.kind == "prefill":
        bspecs, baxes = batch_specs(cfg, shape)
        bshard = {k: rules.spec(baxes[k], bspecs[k].shape) for k in baxes}
        acache = lm.abstract_cache(cfg, shape.global_batch, shape.seq_len)
        cspecs = lm.cache_pspecs(cfg, shape.global_batch, shape.seq_len,
                                 rules)

        def prefill_fn(params, batch, cache):
            return lm.prefill(params, cfg, batch, cache)

        fn = jax.jit(prefill_fn,
                     in_shardings=(_ns(mesh, pspecs), _ns(mesh, bshard),
                                   _ns(mesh, cspecs)),
                     donate_argnums=(2,))
        return rules, fn, (aparams, bspecs, acache)

    # decode
    tokens, lengths, acache, _ = decode_specs(cfg, shape)
    cspecs = lm.cache_pspecs(cfg, shape.global_batch, shape.seq_len, rules)

    def decode_fn(params, tok, lens, cache):
        return lm.decode_step(params, cfg, tok, lens, cache)

    fn = jax.jit(decode_fn,
                 in_shardings=(_ns(mesh, pspecs),
                               NamedSharding(mesh, rules.spec(
                                   ("batch", "seq"), tokens.shape)),
                               NamedSharding(mesh, rules.spec(
                                   ("batch",), lengths.shape)),
                               _ns(mesh, cspecs)),
                 donate_argnums=(3,))
    return rules, fn, (aparams, tokens, lengths, acache)


def _tiny_shape(shape, mesh):
    """Shrink a standard shape so a tiny config compiles in CPU-test time
    while every mesh axis still has work to shard (batch >= data slice)."""
    data = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                        if a != "model"]))
    return dataclasses.replace(
        shape, seq_len=min(shape.seq_len, 128),
        global_batch=max(min(shape.global_batch, 16), data),
        microbatches=1)


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             force: bool = False, save_hlo: bool = False,
             mesh=None, tiny: bool = False, strict: bool = False) -> dict:
    """Lower + compile one (arch, shape, mesh) cell and record its
    accounting.  Default mesh is the production 16x16 / 2x16x16
    construction; ``mesh=`` substitutes any other ``launch.mesh`` mesh
    (e.g. ``make_mesh((4, 2))`` on 8 virtual host devices), and ``tiny``
    swaps in the arch's reduced CPU config with a shrunken shape — the
    same build/rules/compile path end-to-end at container scale."""
    if mesh is not None:
        mesh_tag = "mesh" + "x".join(str(mesh.shape[a])
                                     for a in mesh.axis_names)
    else:
        mesh_tag = "pod2" if multi_pod else "pod1"
    # tiny cells must never collide with production cell ids: they would
    # poison the incremental artifact cache and the *__pod[12].json
    # production contract (tests/test_system.py).
    mesh_tag += "_tiny" if tiny else ""
    cell_id = f"{arch}__{shape_name}__{mesh_tag}"
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    out_path = ARTIFACTS / f"{cell_id}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg, meta = registry.get(arch)
    if tiny:
        cfg = registry.get_tiny(arch)
    shapes = standard_shapes(meta.train_microbatches)
    shape = shapes[shape_name]
    rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
           "mesh": "x".join(str(s) for s in mesh.devices.shape)
           if mesh is not None else ("2x16x16" if multi_pod else "16x16"),
           "ok": False}

    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec.update(skipped=True, reason=why, ok=True)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    t0 = time.time()
    try:
        # Mesh construction belongs inside the try: too few (virtual)
        # devices for the requested mesh is a per-cell failure to record,
        # not a reason to abort the whole sweep.
        if mesh is None:
            mesh = make_production_mesh(multi_pod=multi_pod)
        if tiny:
            shape = _tiny_shape(shape, mesh)
        rules, fn, args = build_cell(cfg, meta, shape, mesh)
        with use_mesh(mesh, rules):
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = xla_cost(compiled)
        n_dev = int(np.prod(mesh.devices.shape))
        mem_d = {}
        for f in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "temp_size_in_bytes",
                  "alias_size_in_bytes", "peak_memory_in_bytes"):
            v = getattr(mem, f, None)
            if v is not None:
                mem_d[f] = int(v)
        coll = collective_stats(compiled.as_text(), n_dev)
        if save_hlo:
            (ARTIFACTS / f"{cell_id}.hlo.txt").write_text(compiled.as_text())
        rec.update(
            ok=True, devices=n_dev,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            memory=mem_d,
            collectives=coll,
            model_flops=analytic_model_flops(cfg, shape),
            microbatches=shape.microbatches if shape.kind == "train" else 1,
        )
    except Exception as e:  # record the failure; dry-run failures are bugs
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if strict:
            # Persist the record first so the artifact survives, then
            # surface the original exception to the caller/CI.
            out_path.write_text(json.dumps(rec, indent=1))
            raise
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="both")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--devices", type=int, default=512,
                    help="virtual host device count (set pre-jax-import)")
    ap.add_argument("--mesh", type=str, default=None,
                    help="explicit DxM mesh over the virtual devices, e.g. "
                         "4x2 = (data=4, model=2) — replaces the production "
                         "mesh so sub-production cells run end-to-end")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced per-arch CPU config + shrunken shape")
    ap.add_argument("--strict", action="store_true",
                    help="re-raise the first cell failure (after writing "
                         "its artifact) instead of recording and "
                         "continuing — fail-fast mode for CI")
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        shape = tuple(int(s) for s in args.mesh.split("x"))
        mesh = make_mesh(shape, ("data", "model")[:len(shape)])
    pods = [False] if mesh is not None else \
        {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    archs = [args.arch] if args.arch else [a.replace("_", "-")
                                           for a in registry.ARCHS]
    shapes = [args.shape] if args.shape else list(standard_shapes())

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                t0 = time.time()
                rec = run_cell(arch, shape, mp, force=args.force,
                               save_hlo=args.save_hlo, mesh=mesh,
                               tiny=args.tiny, strict=args.strict)
                status = "SKIP" if rec.get("skipped") else (
                    "ok" if rec["ok"] else "FAIL")
                n_fail += 0 if rec["ok"] else 1
                extra = rec.get("reason", rec.get("error", ""))
                peak = rec.get("memory", {}).get("peak_memory_in_bytes")
                peak_s = f" peak={peak/2**30:.2f}GiB" if peak else ""
                print(f"[{status:4s}] {rec['cell']:50s} "
                      f"{time.time()-t0:7.1f}s{peak_s} {extra}", flush=True)
    print(f"dry-run complete, failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
