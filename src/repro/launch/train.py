"""Training launcher: ``python -m repro.launch.train --arch yi-6b [--tiny]``.

On real hardware this runs under the production mesh with the cell's
shardings; on this container use ``--tiny`` (reduced config, 1 device) for
an end-to-end run — examples/quickstart.py wraps exactly that.
"""

from __future__ import annotations

import argparse

from repro.configs import registry
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args(argv)

    if args.tiny:
        cfg = registry.get_tiny(args.arch)
    else:
        cfg, _meta = registry.get(args.arch)

    t = Trainer(cfg, TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, lr=args.lr,
        microbatches=args.microbatches,
        global_batch=args.global_batch, seq_len=args.seq_len))
    t.install_signal_handlers()
    out = t.run()
    hist = out["history"]
    print(f"arch={cfg.name} steps={out['step']} "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"stragglers={len(out['stragglers'])} "
          f"preempted={out['preempted']}")
    return out


if __name__ == "__main__":
    main()
