"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A function, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS *before* any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a (data, model) mesh (tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_mesh(shape, axes=("data", "model")):
    """Mesh of the local devices with an explicit logical shape — the
    sub-production construction dry-runs and CI use with host-platform
    device virtualization (``--xla_force_host_platform_device_count=N``)."""
    import math
    n = len(jax.devices())
    if math.prod(shape) > n:
        raise ValueError(f"mesh shape {shape} needs {math.prod(shape)} "
                         f"devices, only {n} present")
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_sweep_mesh(n_devices=None, axis="data"):
    """1-D data-parallel mesh for ``simlock.sweep(..., mesh=)``: the sweep's
    cell dimension shards over ``axis``.  Defaults to every local device."""
    n = len(jax.devices()) if n_devices is None else n_devices
    return make_mesh((n,), (axis,))
