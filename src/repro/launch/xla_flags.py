"""Pre-jax-import ``XLA_FLAGS`` composition, shared by every entry point
(benchmark drivers, the dry-run CLI, the test session).

Never imports jax — these knobs are only effective when set *before* the
first jax import.  Flags are **prepended**: XLA's flag parser stops at
the first token without a ``--`` prefix, so anything appended after a
caller's bare token (e.g. a stray ``intra_op_parallelism_threads=1``)
would be silently dropped.
"""

from __future__ import annotations

import os

HOST_DEVICE_FLAG = "xla_force_host_platform_device_count"


def prepend(*flags: str) -> None:
    """Add ``flags`` to XLA_FLAGS, skipping any whose name (the part
    before ``=``) the caller already set — the environment wins.  The
    result is reordered so every ``--``-prefixed flag precedes any bare
    token (ours or the caller's): the parser would silently drop flags
    after the first bare token otherwise."""
    cur = os.environ.get("XLA_FLAGS", "").split()
    names = {t.split("=", 1)[0].lstrip("-") for t in cur}
    toks = [f for f in flags
            if f.split("=", 1)[0].lstrip("-") not in names] + cur
    os.environ["XLA_FLAGS"] = " ".join(
        [t for t in toks if t.startswith("--")] +
        [t for t in toks if not t.startswith("--")])


def ensure_host_devices(n) -> None:
    """Virtualize ``n`` host-platform devices (CPU containers standing in
    for a real mesh).  No-op when the caller already pinned a count."""
    prepend(f"--{HOST_DEVICE_FLAG}={n}")


def argv_device_count(argv, default):
    """Read ``--devices N`` / ``--devices=N`` from raw ``argv`` — needed
    before argparse can run because jax must not be imported yet."""
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith("--devices="):
            return a.split("=", 1)[1]
    return default
