"""Serving launcher: continuous batching with a selectable admission policy.

``python -m repro.launch.serve --arch yi-6b --tiny --scheduler asl`` runs a
real (tiny) model under load: jitted prefill/decode steps driven by the
engine loop with the paper's ASL admission; prints throughput + TTFT/ITL
tails vs the SLO.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.serving.engine import CostModel, ServingEngine, poisson_workload


def calibrated_cost(cfg, *, batch=8, prefill_chunk=256, t_cache=512) -> CostModel:
    """Measure real step times of the jitted model (tiny configs on CPU)."""
    params = lm.init_params(cfg, 0)
    cache = lm.init_cache(cfg, batch, t_cache)
    pre = jax.jit(lambda p, b, c: lm.prefill(p, cfg, b, c))
    dec = jax.jit(lambda p, t, l, c: lm.decode_step(p, cfg, t, l, c))
    toks = jnp.ones((batch, prefill_chunk), jnp.int32)
    logits, cache = pre(params, {"tokens": toks}, cache)   # compile
    lengths = jnp.full((batch,), prefill_chunk, jnp.int32)
    tok = jnp.ones((batch, 1), jnp.int32)
    logits2, cache, lengths = dec(params, tok, lengths, cache)  # compile
    t0 = time.monotonic()
    for _ in range(5):
        logits, _ = pre(params, {"tokens": toks},
                        lm.init_cache(cfg, batch, t_cache))
    jax.block_until_ready(logits)
    t_pre = (time.monotonic() - t0) / 5
    t0 = time.monotonic()
    for _ in range(20):
        logits2, cache, lengths = dec(params, tok, lengths, cache)
    jax.block_until_ready(logits2)
    t_dec = (time.monotonic() - t0) / 20
    return CostModel(decode_step_s=t_dec, prefill_chunk_s=t_pre,
                     prefill_chunk=prefill_chunk, max_batch=batch)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--scheduler", choices=["fifo", "greedy", "asl"],
                    default="asl")
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--duration", type=float, default=30.0)
    ap.add_argument("--slo-ttft", type=float, default=0.25)
    args = ap.parse_args(argv)

    cfg = registry.get_tiny(args.arch) if args.tiny \
        else registry.get(args.arch)[0]
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no serving path")
    cost = calibrated_cost(cfg)
    print(f"calibrated: decode={cost.decode_step_s*1e3:.2f}ms "
          f"prefill_chunk={cost.prefill_chunk_s*1e3:.2f}ms")
    kw = {"default_window": 0.02, "max_window": 10.0} \
        if args.scheduler == "asl" else {}
    eng = ServingEngine(args.scheduler, cost, scheduler_kwargs=kw)
    poisson_workload(eng, rate_rps=args.rate, duration_s=args.duration,
                     prompt_lens=[512, 1024, 2048], new_tokens=[32, 128],
                     slo_ttft=args.slo_ttft)
    m = eng.metrics()
    print(f"scheduler={args.scheduler} n={m['n']} "
          f"tok/s={m['throughput_tok_s']:.0f} "
          f"ttft_p99={m['ttft_p99']*1e3:.1f}ms "
          f"itl_p99={m['itl_p99']*1e3:.1f}ms "
          f"viol={m['slo_violation_rate']:.1%}")
    return m


if __name__ == "__main__":
    main()
