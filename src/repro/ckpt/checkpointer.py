"""Fault-tolerant checkpointing: atomic sharded save, resharding restore.

* **Atomic**: writes into ``step_XXXX.tmp/`` then ``os.rename`` — a crash
  mid-save never corrupts the latest checkpoint; restore scans for the
  newest complete directory (rename is the commit point).
* **Sharded**: each leaf is saved as a raw ``.npy``; on a multi-host pod
  each host writes only the leaves (or leaf shards) it owns — here
  single-process, the layout is the same, keyed by flattened tree paths.
* **Resharding restore**: ``restore`` takes the *target* abstract tree and
  shardings; arrays are loaded host-side and ``jax.device_put`` against the
  new mesh, so a 2-pod checkpoint restarts on 1 pod (elastic downscale) and
  vice versa — the elastic-restart test exercises exactly that.
* The manager thread and keep-policy GC are guarded by the paper's LibASL
  mutex (saves are little-core/standby work; the training step's metadata
  read is the latency-critical path).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

from repro.core.libasl import LibASL

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _leaf_names(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["_".join(re.sub(r"[^A-Za-z0-9_]", "", str(k)) for k in path)
            or f"leaf{i}" for i, (path, _) in enumerate(paths)]


def save(directory, step: int, tree) -> Path:
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f"step_{step}.tmp"
    final = d / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    names = _leaf_names(tree)
    leaves, _ = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"{name}.npy", arr)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)           # commit point
    return final


def latest_step(directory) -> int | None:
    d = Path(directory)
    if not d.exists():
        return None
    steps = [int(m.group(1)) for p in d.iterdir()
             if (m := _STEP_RE.match(p.name)) and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(directory, step: int, target_tree, shardings=None):
    """Load into the *target* structure; device_put against new shardings."""
    d = Path(directory) / f"step_{step}"
    names = _leaf_names(target_tree)
    leaves, treedef = _flatten(target_tree)
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves))
    out = []
    for name, tgt, sh in zip(names, leaves, shard_leaves):
        arr = np.load(d / f"{name}.npy")
        want_shape = tuple(tgt.shape)
        assert arr.shape == want_shape, (name, arr.shape, want_shape)
        arr = arr.astype(tgt.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Keep-policy + async save thread + crash-safe latest()."""

    def __init__(self, directory, keep: int = 3, save_async: bool = True):
        self.dir = Path(directory)
        self.keep = keep
        self._async = save_async
        self._asl = LibASL(is_big_core=lambda: not _in_saver())
        self._mu = self._asl.mutex()
        self._pending: threading.Thread | None = None

    def save(self, step: int, tree):
        tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self._async:
            self.wait()
            t = threading.Thread(target=self._do_save, args=(step, tree),
                                 daemon=True)
            self._pending = t
            t.start()
        else:
            self._do_save(step, tree)

    def _do_save(self, step, tree):
        _SAVER.flag = True
        with self._mu:
            save(self.dir, step, tree)
            self._gc()

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for p in self.dir.iterdir()
            if (m := _STEP_RE.match(p.name)))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def latest(self) -> int | None:
        with self._mu:
            return latest_step(self.dir)

    def restore(self, step, target_tree, shardings=None):
        self.wait()
        with self._mu:
            return restore(self.dir, step, target_tree, shardings)


_SAVER = threading.local()


def _in_saver() -> bool:
    return getattr(_SAVER, "flag", False)
