"""Train / serve step builders (remat + gradient-accumulation scan).

``make_train_step`` returns a pure function
``(params, opt_state, step, batch) -> (params, opt_state, step, metrics)``
suitable for ``jax.jit`` with donated state.  Microbatching runs as a
``lax.scan`` over the leading batch split, accumulating grads in
``cfg.grad_accum_dtype`` (fp32 default; bf16 for the 405B memory budget).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import DTYPES, ModelConfig, ShapeSpec
from repro.optim.adamw import AdamW, clip_by_global_norm


def make_train_step(cfg: ModelConfig, opt: AdamW, lr_fn, *,
                    microbatches: int = 1, clip_norm: float = 1.0,
                    unroll_accum: bool = False):
    """``unroll_accum`` unrolls the microbatch loop in the HLO — used by the
    roofline analysis, where scan bodies are cost-counted only once."""
    acc_dt = DTYPES[getattr(cfg, "grad_accum_dtype", "float32")]

    def loss_fn(params, mb):
        return lm.loss_fn(params, cfg, mb)

    def train_step(params, opt_state, step, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        elif unroll_accum:
            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            grads = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt),
                                 params)
            loss = jnp.zeros((), jnp.float32)
            for i in range(microbatches):
                mb = jax.tree.map(lambda x: x[i], mbs)
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                grads = jax.tree.map(lambda a, b: a + b.astype(acc_dt),
                                     grads, g)
                loss = loss + l
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {"loss": loss}
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), g_acc, g)
                return (g_acc, l_acc + l), None

            (grads, loss), _ = jax.lax.scan(
                acc, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {"loss": loss}

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = opt.update(grads, opt_state, params, lr_fn(step))
        metrics = dict(metrics, grad_norm=gnorm, lr=lr_fn(step))
        return params, opt_state, step + 1, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, cache):
        return lm.prefill(params, cfg, batch, cache)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, tokens, lengths, cache):
        return lm.decode_step(params, cfg, tokens, lengths, cache)
    return decode_step
