"""Fault-tolerant training loop: checkpoint/restart, preemption handling,
straggler mitigation hooks, elastic re-meshing.

Designed for 1000+ node operation (DESIGN.md §6):

* **Restart-safe**: the step counter keys both the data stream (stateless
  bijective shuffle) and the LR schedule, so `restore -> resume` is
  bit-identical to an uninterrupted run (tested).
* **Preemption**: SIGTERM/SIGINT set a flag; the loop checkpoints at the
  next step boundary and exits cleanly (maintenance events on TPU pods).
* **Elastic**: ``restore`` takes the *current* mesh's shardings — a
  checkpoint written on 2 pods restarts on 1 pod or vice versa.
* **Straggler hook**: a :class:`BoundedStalenessController` decides whether
  this pod may commit ahead (multi-pod; policy-only on one host).
* Step-time anomaly detection: a step slower than ``straggler_factor`` x
  the EWMA is logged as a straggler event (the signal a fleet scheduler
  would use to trigger hot-spares).
"""

from __future__ import annotations

import dataclasses
import signal
import time

import jax
import numpy as np

from repro.ckpt.checkpointer import CheckpointManager
from repro.data.pipeline import DataConfig, TokenDataset
from repro.dist.staleness import BoundedStalenessController
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    lr: float = 3e-4
    warmup: int = 10
    microbatches: int = 1
    global_batch: int = 8
    seq_len: int = 128
    seed: int = 0
    straggler_factor: float = 3.0
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig, *,
                 shardings=None, staleness: BoundedStalenessController = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.opt = AdamW(state_dtype=cfg.opt_state_dtype)
        self.lr_fn = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.total_steps)
        self.step_fn = jax.jit(make_train_step(
            cfg, self.opt, self.lr_fn, microbatches=tcfg.microbatches),
            donate_argnums=(0, 1))
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep,
                                      save_async=False)
        self.data = TokenDataset(DataConfig(
            vocab=cfg.vocab, seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, seed=tcfg.seed))
        self.shardings = shardings
        self.staleness = staleness
        self._preempted = False
        self.history: list[dict] = []
        self.straggler_events: list[int] = []

    # ------------------------------------------------------------------
    def install_signal_handlers(self):
        def _handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, _handler)
        signal.signal(signal.SIGUSR1, _handler)

    # ------------------------------------------------------------------
    def init_or_restore(self):
        params = lm.init_params(self.cfg, self.tcfg.seed)
        opt_state = self.opt.init(params)
        step = 0
        latest = self.ckpt.latest()
        if latest is not None:
            tree = {"params": params, "opt": opt_state}
            restored = self.ckpt.restore(latest, tree, self.shardings)
            params, opt_state = restored["params"], restored["opt"]
            step = latest
        return params, opt_state, step

    # ------------------------------------------------------------------
    def run(self, max_steps: int = None) -> dict:
        params, opt_state, step = self.init_or_restore()
        step_j = jax.numpy.int32(step)
        horizon = min(self.tcfg.total_steps,
                      (step + max_steps) if max_steps else
                      self.tcfg.total_steps)
        ewma = None
        while step < horizon and not self._preempted:
            if self.staleness is not None and \
                    not self.staleness.can_commit(0):
                time.sleep(0.01)    # bounded: wait for the slowest pod
                continue
            batch = self.data.batch(step)
            t0 = time.monotonic()
            params, opt_state, step_j, metrics = self.step_fn(
                params, opt_state, step_j,
                jax.tree.map(jax.numpy.asarray, batch))
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > self.tcfg.straggler_factor * ewma and step > 2:
                self.straggler_events.append(step)
            step += 1
            if self.staleness is not None:
                self.staleness.commit(0)
            self.history.append({"step": step, "loss": loss, "dt": dt})
            if step % self.tcfg.ckpt_every == 0 or self._preempted or \
                    step >= horizon:
                self.ckpt.save(step, {"params": params, "opt": opt_state})
        if self._preempted:
            self.ckpt.save(step, {"params": params, "opt": opt_state})
        return {"step": step, "params": params, "opt": opt_state,
                "history": self.history, "preempted": self._preempted,
                "stragglers": self.straggler_events}
