# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

def _missing_compiler_params(*_a, **_k):  # pragma: no cover
    raise ImportError(
        "jax.experimental.pallas.tpu (or its CompilerParams /"
        " TPUCompilerParams) is unavailable in this jax build; the pure-"
        "NumPy reference path (repro.kernels.ref) still works — update "
        "repro/kernels/__init__.py for the new Pallas API to use the "
        "TPU kernels")


# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both,
# and degrade to a deferred error (not an import-time crash) so the
# reference implementations stay importable on pallas-less jax builds.
try:  # pragma: no cover - exercised only on minimal jax wheels
    from jax.experimental.pallas import tpu as _pltpu
    CompilerParams = getattr(
        _pltpu, "CompilerParams",
        getattr(_pltpu, "TPUCompilerParams", _missing_compiler_params))
except ImportError:
    CompilerParams = _missing_compiler_params
