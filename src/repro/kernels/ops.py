"""Jit'd dispatch wrappers for the Pallas kernels.

On this CPU container kernels execute in ``interpret=True`` mode (the body
runs as Python/XLA ops — correctness only).  On a real TPU set
``repro.kernels.ops.INTERPRET = False`` (or env ``REPRO_PALLAS_COMPILE=1``)
and the same call sites compile to Mosaic.  The model layers call these via
``use_pallas=True`` config paths; the jnp fallbacks are the ref oracles.
"""

from __future__ import annotations

import functools
import os

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mlstm_scan import mlstm_scan as _mlstm
from repro.kernels.rglru_scan import rglru_scan as _rglru

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def _pick_block(n, target):
    b = min(n, target)
    while n % b:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal=True, window=0):
    s, t = q.shape[2], k.shape[2]
    bq = _pick_block(s, 512)
    bk = _pick_block(t, 512)
    if bq < 8 or bk < 8:     # degenerate tiling: use the oracle
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash(q, k, v, causal=causal, window=window, block_q=bq,
                  block_k=bk, interpret=INTERPRET)


@jax.jit
def decode_attention(q, k_cache, v_cache, lengths):
    t = k_cache.shape[2]
    bk = _pick_block(t, 512)
    if bk < 8:
        return ref.decode_attention_ref(q, k_cache, v_cache, lengths)
    return _decode(q, k_cache, v_cache, lengths, block_k=bk,
                   interpret=INTERPRET)


@jax.jit
def rglru_scan(a, x, h0=None):
    s, r = a.shape[1], a.shape[2]
    bs = _pick_block(s, 256)
    bc = _pick_block(r, 256)
    if bs < 8 or bc < 8:
        return ref.rglru_scan_ref(a, x, h0)
    return _rglru(a, x, h0, block_s=bs, block_c=bc, interpret=INTERPRET)


@jax.jit
def mlstm_scan(q, k, v, i_gate, f_gate, carry=None):
    s = q.shape[2]
    bs = _pick_block(s, 128)
    if bs < 8:
        return ref.mlstm_scan_ref(q, k, v, i_gate, f_gate, carry)
    return _mlstm(q, k, v, i_gate, f_gate, carry, block_s=bs,
                  interpret=INTERPRET)
