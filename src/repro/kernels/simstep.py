"""Pallas kernel for the simulator's fused masked step.

One :func:`fused_chunk` call retires a whole ``cfg.chunk`` of events
inside a single ``pl.pallas_call``: the traced pytrees (``SimTables``,
``SimParams``, ``SimState``) are *packed* — leaves grouped by
(dtype, shape) and stacked into a few i32/f32/u32 vectors — handed to
the kernel as whole-array VMEM refs, unpacked back into pytrees inside
the kernel, and the per-event step (argmin over the event clock +
masked scatter/gather handler updates) runs as an in-kernel
``lax.scan``.  On a TPU the whole hot state is then VMEM-resident for
the duration of the chunk instead of bouncing per-op through HBM.

The step callable itself is the engine's ``simlock._step`` closure —
the kernel adds no semantics of its own, so results are bit-identical
to the plain jnp lowering (``tests/test_fused.py`` asserts exact
equality across every registered policy).  On this CPU container the
kernel executes in ``interpret=True`` mode (the body runs as traced
XLA ops — correctness only); set env ``REPRO_PALLAS_COMPILE=1`` on a
real TPU to compile it to Mosaic, exactly like ``repro.kernels.ops``.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def _group(leaves) -> dict:
    """Leaf indices grouped by (dtype, shape) — the packing layout.
    Insertion-ordered, so pack/unpack agree across call and kernel."""
    groups: dict = {}
    for i, x in enumerate(leaves):
        key = (jnp.dtype(x.dtype).name, tuple(jnp.shape(x)))
        groups.setdefault(key, []).append(i)
    return groups


def _pack(leaves, groups):
    return [jnp.stack([leaves[i] for i in idx]) for idx in groups.values()]


def _unpack_refs(refs, groups, n_leaves):
    """Read each packed ref back into per-leaf arrays (ref[j] is a
    load, so after this the kernel computes on values, not refs)."""
    out = [None] * n_leaves
    for r, idx in zip(refs, groups.values()):
        for j, i in enumerate(idx):
            out[i] = r[j]
    return out


def _unpack_arrays(arrs, groups, n_leaves):
    out = [None] * n_leaves
    for a, idx in zip(arrs, groups.values()):
        for j, i in enumerate(idx):
            out[i] = a[j]
    return out


def fused_chunk(step, tb, pm, st, chunk: int, *, interpret=None):
    """Advance ``st`` by ``chunk`` events of ``step`` in one kernel.

    ``step(tb, pm, st) -> st`` must be shape-preserving and already
    horizon-guarded (the engine's live-guard retires past-horizon
    steps as no-ops, which is what makes a fixed-size chunk safe).
    ``interpret=None`` follows the module :data:`INTERPRET` switch.
    """
    if interpret is None:
        interpret = INTERPRET
    # Pallas kernels may not close over constant arrays (e.g. the
    # engine's horizon scalar — jax.closure_convert would leave such
    # integer consts baked in): trace the step to a jaxpr and hoist
    # ALL its consts into explicit inputs, packed with the read-only
    # tree.
    closed = jax.make_jaxpr(step)(tb, pm, st)
    consts = tuple(closed.consts)
    out_def = jax.tree_util.tree_structure(st)

    def step_c(tb_, pm_, st_, consts_):
        flat = jax.tree_util.tree_leaves((tb_, pm_, st_))
        out = jax.core.eval_jaxpr(closed.jaxpr, list(consts_), *flat)
        return jax.tree_util.tree_unflatten(out_def, out)

    ro_leaves, ro_def = jax.tree_util.tree_flatten((tb, pm, consts))
    st_leaves, st_def = jax.tree_util.tree_flatten(st)
    ro_groups = _group(ro_leaves)
    st_groups = _group(st_leaves)
    ro_packed = _pack(ro_leaves, ro_groups)
    st_packed = _pack(st_leaves, st_groups)
    n_ro, n_st = len(ro_packed), len(st_packed)

    def kernel(*refs):
        ro_refs = refs[:n_ro]
        st_refs = refs[n_ro:n_ro + n_st]
        out_refs = refs[n_ro + n_st:]
        tb_k, pm_k, consts_k = jax.tree_util.tree_unflatten(
            ro_def, _unpack_refs(ro_refs, ro_groups, len(ro_leaves)))
        st_k = jax.tree_util.tree_unflatten(
            st_def, _unpack_refs(st_refs, st_groups, len(st_leaves)))

        def body(s, _):
            return step_c(tb_k, pm_k, s, consts_k), None

        st_out = jax.lax.scan(body, st_k, None, length=max(chunk, 1))[0]
        out_leaves = jax.tree_util.tree_leaves(st_out)
        for r, idx in zip(out_refs, st_groups.values()):
            r[...] = jnp.stack([out_leaves[i] for i in idx])

    outs = pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct(x.shape, x.dtype)
                   for x in st_packed],
        interpret=interpret,
    )(*ro_packed, *st_packed)
    return jax.tree_util.tree_unflatten(
        st_def, _unpack_arrays(outs, st_groups, len(st_leaves)))
