"""mLSTM matrix-memory recurrence as a Pallas TPU kernel.

Per (batch, head): C_t = f' C + i' v k^T with stabilized exponential gates
(see :mod:`repro.kernels.ref`).  TPU adaptation: the [dh, dh] matrix memory
and its normalizer stay **VMEM-resident** across the whole sequence — the
kernel streams q/k/v/gate tiles chunk-by-chunk along the sequential grid
dim, so HBM traffic is exactly one pass over qkv plus one [dh,dh] state
spill at the end, instead of the S outer-product round-trips a naive XLA
scan materializes.  Within a chunk the recurrence is a fori_loop of rank-1
MXU updates; the q readout ``C q`` reuses the resident state.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams


def _kernel(q_ref, k_ref, v_ref, ig_ref, fg_ref, c0_ref, n0_ref, m0_ref,
            o_ref, cT_ref, nT_ref, mT_ref, C_ref, n_ref, m_ref, *, block_s,
            ns):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        C_ref[...] = c0_ref[0].astype(jnp.float32)
        n_ref[...] = n0_ref[0].astype(jnp.float32)
        m_ref[...] = m0_ref[0].astype(jnp.float32)

    q = q_ref[0].astype(jnp.float32)      # [bs, dh]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    ig = ig_ref[0].astype(jnp.float32)    # [bs]
    fg = fg_ref[0].astype(jnp.float32)

    def step(t, carry):
        C, n, m = carry
        log_f = -jax.nn.softplus(-fg[t])
        m_new = jnp.maximum(log_f + m, ig[t])
        i_p = jnp.exp(ig[t] - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        C = f_p * C + i_p * (v[t][:, None] * k[t][None, :])
        n = f_p * n + i_p * k[t]
        num = C @ q[t]
        den = jnp.abs(jnp.dot(n, q[t]))
        o_ref[0, t, :] = (num / jnp.maximum(den, 1.0)).astype(o_ref.dtype)
        return C, n, m_new

    C, n, m = jax.lax.fori_loop(
        0, block_s, step, (C_ref[...], n_ref[...], m_ref[...]))
    C_ref[...] = C
    n_ref[...] = n
    m_ref[...] = m

    @pl.when(si == ns - 1)
    def _final():
        cT_ref[0] = C
        nT_ref[0] = n
        mT_ref[0] = m


def mlstm_scan(q, k, v, i_gate, f_gate, carry=None, *, block_s=128,
               interpret=False):
    """q,k,v: [B,H,S,dh]; gates: [B,H,S] -> (h [B,H,S,dh], (C,n,m))."""
    b, h, s, dh = q.shape
    assert s % block_s == 0
    ns = s // block_s
    if carry is None:
        c0 = jnp.zeros((b * h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b * h, dh), jnp.float32)
        m0 = jnp.full((b * h, 1), -1e30, jnp.float32)
    else:
        C, n, m = carry
        c0 = C.reshape(b * h, dh, dh).astype(jnp.float32)
        n0 = n.reshape(b * h, dh).astype(jnp.float32)
        m0 = m.reshape(b * h, 1).astype(jnp.float32)

    flat = lambda t: t.reshape(b * h, s, -1)
    qf, kf, vf = flat(q), flat(k), flat(v)
    igf = i_gate.reshape(b * h, s)
    fgf = f_gate.reshape(b * h, s)

    kernel = functools.partial(_kernel, block_s=block_s, ns=ns)
    seq_spec = pl.BlockSpec((1, block_s, dh), lambda bh, si: (bh, si, 0))
    gate_spec = pl.BlockSpec((1, block_s), lambda bh, si: (bh, si))
    state_specs = [
        pl.BlockSpec((1, dh, dh), lambda bh, si: (bh, 0, 0)),
        pl.BlockSpec((1, dh), lambda bh, si: (bh, 0)),
        pl.BlockSpec((1, 1), lambda bh, si: (bh, 0)),
    ]
    out, cT, nT, mT = pl.pallas_call(
        kernel,
        grid=(b * h, ns),
        in_specs=[seq_spec, seq_spec, seq_spec, gate_spec, gate_spec,
                  *state_specs],
        out_specs=[seq_spec, *state_specs],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, dh), q.dtype),
            jax.ShapeDtypeStruct((b * h, dh, dh), jnp.float32),
            jax.ShapeDtypeStruct((b * h, dh), jnp.float32),
            jax.ShapeDtypeStruct((b * h, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((dh,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, igf, fgf, c0, n0, m0)
    return (out.reshape(b, h, s, dh),
            (cT.reshape(b, h, dh, dh), nT.reshape(b, h, dh),
             mT.reshape(b, h)))
