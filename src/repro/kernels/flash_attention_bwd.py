"""Flash attention backward as Pallas TPU kernels (+ custom_vjp wiring).

Standard two-kernel decomposition (FlashAttention-2 style, adapted to the
TPU grid model):

* the forward (``flash_attention.py``) additionally returns the softmax
  log-sum-exp rows, so the backward recomputes probabilities block-wise
  instead of storing S x T scores;
* ``dq`` kernel: grid (b, h, q_blocks, kv_blocks) — kv innermost
  sequential, dq tile accumulates in VMEM scratch;
* ``dkv`` kernel: grid (b, h, kv_blocks, q_blocks) — q innermost
  sequential, dk/dv tiles accumulate in VMEM scratch;
* GQA: both kernels run over the *expanded* H heads (index-mapped KV, no
  materialized repeat); the vjp wrapper group-sums dk/dv back to K heads.

``flash_attention_vjp`` is the differentiable entry point: forward = the
fused kernel, backward = these kernels; validated in interpret mode against
``jax.grad`` of the jnp oracle over shape/dtype/mask sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

from repro.kernels.flash_attention import NEG_INF, flash_attention


def _fwd_lse(q, k, v, *, causal, window, block_q, block_k, interpret):
    """Forward output + lse rows (recompute-free backward needs lse)."""
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=block_q, block_k=block_k,
                          interpret=interpret)
    # lse via the oracle formula on block maxima is equivalent to a fused
    # second output; one cheap extra pass keeps the fwd kernel simple.
    b, h, s, dh = q.shape
    g = h // k.shape[1]
    kx = jnp.repeat(k, g, axis=1)
    scores = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) / np.sqrt(dh)
    scores = jnp.where(_mask(s, k.shape[2], causal, window)[None, None],
                       scores, NEG_INF)
    lse = jax.nn.logsumexp(scores, axis=-1)
    return out, lse


def _mask(s, t, causal, window):
    iq = jnp.arange(s)[:, None]
    jk = jnp.arange(t)[None, :]
    m = jnp.ones((s, t), bool)
    if causal:
        m = jk <= iq
        if window:
            m = jnp.logical_and(m, jk > iq - window)
    return m


def _block_mask(q_start, k_start, shape, causal, window):
    iq = q_start + jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    jk = k_start + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    m = jnp.ones(shape, bool)
    if causal:
        m = jk <= iq
        if window:
            m = jnp.logical_and(m, jk > iq - window)
    return m


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_out,
               dq_acc, *, scale, causal, window, block_q, block_k, nk):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_start, k_start = qi * block_q, ki * block_k
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
        if window:
            run = jnp.logical_and(run,
                                  k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        m = _block_mask(q_start, k_start, s.shape, causal, window)
        p = jnp.where(m, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _fin():
        dq_out[0, 0, ...] = dq_acc[...].astype(dq_out.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_out,
                dv_out, dk_acc, dv_acc, *, scale, causal, window, block_q,
                block_k, nq):
    ki, qi = pl.program_id(2), pl.program_id(3)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start, k_start = qi * block_q, ki * block_k
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
        if window:
            run = jnp.logical_and(run,
                                  k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        m = _block_mask(q_start, k_start, s.shape, causal, window)
        p = jnp.where(m, jnp.exp(s - lse[:, None]), 0.0)       # [bq, bk]
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(qi == nq - 1)
    def _fin():
        dk_out[0, 0, ...] = dk_acc[...].astype(dk_out.dtype)
        dv_out[0, 0, ...] = dv_acc[...].astype(dv_out.dtype)


def flash_attention_bwd(q, k, v, out, lse, do, *, causal=True, window=0,
                        block_q=128, block_k=128, interpret=False):
    """-> (dq [B,H,S,dh], dk, dv [B,K,T,dh])."""
    b, h, s, dh = q.shape
    kh, t = k.shape[1], k.shape[2]
    g = h // kh
    nq, nk = s // block_q, t // block_k
    scale = 1.0 / np.sqrt(dh)
    delta = jnp.sum(out.astype(jnp.float32) * do.astype(jnp.float32),
                    axis=-1)                                   # [B,H,S]

    q_spec = pl.BlockSpec((1, 1, block_q, dh),
                          lambda bi, hi, qi, ki: (bi, hi, qi, 0))
    kv_spec = pl.BlockSpec((1, 1, block_k, dh),
                           lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0))
    row_spec = pl.BlockSpec((1, 1, block_q),
                            lambda bi, hi, qi, ki: (bi, hi, qi))
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          nk=nk),
        grid=(b, h, nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, dh), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dk/dv over expanded heads, then group-sum to K heads.
    q_spec2 = pl.BlockSpec((1, 1, block_q, dh),
                           lambda bi, hi, ki, qi: (bi, hi, qi, 0))
    kv_spec2 = pl.BlockSpec((1, 1, block_k, dh),
                            lambda bi, hi, ki, qi, g=g: (bi, hi // g, ki, 0))
    kvh_out2 = pl.BlockSpec((1, 1, block_k, dh),
                            lambda bi, hi, ki, qi: (bi, hi, ki, 0))
    row_spec2 = pl.BlockSpec((1, 1, block_q),
                             lambda bi, hi, ki, qi: (bi, hi, qi))
    dkh, dvh = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          nq=nq),
        grid=(b, h, nk, nq),
        in_specs=[q_spec2, kv_spec2, kv_spec2, q_spec2, row_spec2,
                  row_spec2],
        out_specs=[kvh_out2, kvh_out2],
        out_shape=[jax.ShapeDtypeStruct((b, h, t, dh), k.dtype),
                   jax.ShapeDtypeStruct((b, h, t, dh), v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_k, dh), jnp.float32),
                        pltpu.VMEM((block_k, dh), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    dk = dkh.reshape(b, kh, g, t, dh).sum(axis=2).astype(k.dtype)
    dv = dvh.reshape(b, kh, g, t, dh).sum(axis=2).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_vjp(q, k, v, causal=True, window=0, block_q=128,
                        block_k=128, interpret=False):
    out, _ = _fwd_lse(q, k, v, causal=causal, window=window,
                      block_q=block_q, block_k=block_k, interpret=interpret)
    return out


def _vjp_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    out, lse = _fwd_lse(q, k, v, causal=causal, window=window,
                        block_q=block_q, block_k=block_k,
                        interpret=interpret)
    return out, (q, k, v, out, lse)


def _vjp_bwd(causal, window, block_q, block_k, interpret, res, do):
    q, k, v, out, lse = res
    dq, dk, dv = flash_attention_bwd(
        q, k, v, out, lse, do, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return dq, dk, dv


flash_attention_vjp.defvjp(_vjp_fwd, _vjp_bwd)
