"""RG-LRU linear recurrence as a Pallas TPU kernel.

``h_t = a_t * h_{t-1} + x_t`` over the sequence, per (batch, channel) lane.
TPU adaptation: instead of the GPU block-parallel-scan formulation, we tile
channels into VREG-aligned blocks, keep the carry ``h`` resident in VMEM,
and walk sequence chunks along the innermost sequential grid dim — each
(a, x) tile crosses HBM exactly once and the recurrence itself is pure VPU
elementwise work (there is no matmul to feed the MXU here; the op is
bandwidth-bound by construction, which is why fusing the neighbouring
projections matters more than the scan itself — see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams


def _kernel(a_ref, x_ref, h0_ref, o_ref, h_ref, *, block_s):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)    # [bs, bc]
    x = x_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + x[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, block_s, step, h_ref[...])


def rglru_scan(a, x, h0=None, *, block_s=128, block_c=128, interpret=False):
    """a, x: [B,S,R]; h0: [B,R] -> h sequence [B,S,R]."""
    b, s, r = a.shape
    assert s % block_s == 0 and r % block_c == 0, (s, r)
    if h0 is None:
        h0 = jnp.zeros((b, r), jnp.float32)
    ns, nc = s // block_s, r // block_c

    kernel = functools.partial(_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=(b * nc, ns),
        in_specs=[
            pl.BlockSpec((1, block_s, block_c),
                         lambda bc, si, nc=nc: (bc // nc, si, bc % nc)),
            pl.BlockSpec((1, block_s, block_c),
                         lambda bc, si, nc=nc: (bc // nc, si, bc % nc)),
            pl.BlockSpec((1, block_c),
                         lambda bc, si, nc=nc: (bc // nc, bc % nc)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_c),
                               lambda bc, si, nc=nc: (bc // nc, si, bc % nc)),
        out_shape=jax.ShapeDtypeStruct((b, s, r), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_c,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, x, h0)
