"""Flash attention (forward) as a Pallas TPU kernel.

Design (TPU-native, not a CUDA port):

* grid = (batch, q_heads, q_blocks, kv_blocks); the kv_blocks dim is the
  innermost *sequential* ("arbitrary") dimension, so the online-softmax
  running state (m, l, acc) lives in VMEM scratch that persists across kv
  steps for one (b, h, qi) output tile — the MXU sees [block_q, d] x
  [d, block_k] matmuls with fp32 accumulation.
* GQA without KV expansion: the K/V BlockSpec index_map folds the
  q-head -> kv-head mapping (``h // group``), so grouped heads stream the
  same KV tile from HBM (XLA would materialize the repeat).
* Causal + local-window masking at block granularity: fully-masked kv
  blocks are skipped with ``pl.when`` (halves the work for causal; for a
  2048-window at 32k the kernel touches only ~1/16 of the blocks).
* block_q x head_dim tiles are MXU/VREG aligned (multiples of (8, 128) for
  f32, (16, 128) bf16); callers pick block sizes via ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, block_q, block_k, nk, kv_len):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # Block-level skip: causal => skip blocks entirely above the diagonal;
    # local window => skip blocks entirely left of the window.
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
        if window:
            run = jnp.logical_and(
                run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)            # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        iq = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        jk = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jk < kv_len
        if causal:
            mask = jnp.logical_and(mask, jk <= iq)
            if window:
                mask = jnp.logical_and(mask, jk > iq - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=False):
    """q: [B,H,S,dh]; k,v: [B,K,T,dh] -> [B,H,S,dh]."""
    b, h, s, dh = q.shape
    kh, t = k.shape[1], k.shape[2]
    g = h // kh
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    nq, nk = s // block_q, t // block_k
    scale = 1.0 / np.sqrt(dh)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk, kv_len=t)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running denom
            pltpu.VMEM((block_q, dh), jnp.float32),   # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
