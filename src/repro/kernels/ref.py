"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: [B,H,S,dh]; k,v: [B,K,T,dh] (GQA: H % K == 0) -> [B,H,S,dh]."""
    b, h, s, dh = q.shape
    kh, t = k.shape[1], k.shape[2]
    g = h // kh
    qf = q.astype(jnp.float32).reshape(b, kh, g, s, dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qf, kf) / np.sqrt(dh)
    iq = jnp.arange(s)[:, None]
    jk = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask = jk <= iq
        if window:
            mask = jnp.logical_and(mask, jk > iq - window)
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, vf)
    return out.reshape(b, h, s, dh).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: [B,H,dh]; caches: [B,K,T,dh]; lengths: [B] -> [B,H,dh]."""
    b, h, dh = q.shape
    kh, t = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qf = q.astype(jnp.float32).reshape(b, kh, g, dh)
    scores = jnp.einsum("bkgd,bktd->bkgt", qf,
                        k_cache.astype(jnp.float32)) / np.sqrt(dh)
    valid = jnp.arange(t)[None, :] < lengths[:, None]          # [B,T]
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", probs,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, h, dh).astype(q.dtype)


def rglru_scan_ref(a, x, h0=None):
    """h_t = a_t * h_{t-1} + x_t, fp32. a,x: [B,S,R]; h0: [B,R]."""
    af = a.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    b, s, r = a.shape
    h = jnp.zeros((b, r), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, ax):
        at, xt = ax
        h = at * h + xt
        return h, h

    _, hs = jax.lax.scan(step, h, (jnp.swapaxes(af, 0, 1),
                                   jnp.swapaxes(xf, 0, 1)))
    return jnp.swapaxes(hs, 0, 1).astype(a.dtype)


def mlstm_scan_ref(q, k, v, i_gate, f_gate, carry=None):
    """Stabilized mLSTM recurrence (the model's semantics).

    q,k,v: [B,H,S,dh] (k pre-scaled); gates: [B,H,S]. -> h: [B,H,S,dh].
    """
    b, h, s, dh = q.shape
    if carry is None:
        C = jnp.zeros((b, h, dh, dh), jnp.float32)
        n = jnp.zeros((b, h, dh), jnp.float32)
        m = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C, n, m = carry

    def step(cr, xs):
        C, n, m = cr
        qt, kt, vt, it, ft = xs
        log_f = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(log_f + m, it)
        i_p = jnp.exp(it - m_new)[..., None]
        f_p = jnp.exp(log_f + m - m_new)[..., None]
        C = f_p[..., None] * C + i_p[..., None] * (vt[..., :, None] *
                                                   kt[..., None, :])
        n = f_p * n + i_p * kt
        num = jnp.einsum("bhij,bhj->bhi", C, qt)
        den = jnp.abs(jnp.einsum("bhj,bhj->bh", n, qt))[..., None]
        return (C, n, m_new), num / jnp.maximum(den, 1.0)

    xs = (jnp.moveaxis(q.astype(jnp.float32), 2, 0),
          jnp.moveaxis(k.astype(jnp.float32), 2, 0),
          jnp.moveaxis(v.astype(jnp.float32), 2, 0),
          jnp.moveaxis(i_gate.astype(jnp.float32), 2, 0),
          jnp.moveaxis(f_gate.astype(jnp.float32), 2, 0))
    carry, hs = jax.lax.scan(step, (C, n, m), xs)
    return jnp.moveaxis(hs, 0, 2).astype(q.dtype), carry
