"""Split-K decode attention as a Pallas TPU kernel (flash-decoding style).

decode_32k is memory-bound: one query token reads the whole KV cache.  The
kernel streams the cache in ``block_k`` VMEM tiles along the innermost
sequential grid dim with an online-softmax accumulator, like flash
attention, but the query tile is the *GQA group*: the g q-heads that share
one kv head form the tile rows (padded to the 8-row VREG granule), so the
MXU runs [g, d] x [d, block_k] instead of degenerate [1, d] work.

Valid-length masking uses scalar-prefetched ``lengths`` (SMEM) — the block
grid is sized for the full cache but fully-invalid blocks are skipped, so
short sequences don't pay for the ring capacity.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale, block_k, nk):
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[bi]
    k_start = ki * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [g, d]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        jk = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(jk < length, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, block_k=256,
                     interpret=False):
    """q: [B,H,dh]; caches: [B,K,T,dh]; lengths: [B] -> [B,H,dh]."""
    b, h, dh = q.shape
    kh, t = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    assert t % block_k == 0, (t, block_k)
    nk = t // block_k
    scale = 1.0 / np.sqrt(dh)
    qg = q.reshape(b, kh, g, dh)

    kernel = functools.partial(_kernel, scale=scale, block_k=block_k, nk=nk)
    out = _call_with_prefetch(kernel, qg, k_cache, v_cache, lengths, b, kh,
                              g, dh, block_k, nk, interpret)
    return out.reshape(b, h, dh)


def _call_with_prefetch(kernel, qg, k_cache, v_cache, lengths, b, kh, g, dh,
                        block_k, nk, interpret):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh),
                         lambda bi, hi, ki, lens: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bi, hi, ki, lens: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bi, hi, ki, lens: (bi, hi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda bi, hi, ki, lens: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, dh), qg.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
