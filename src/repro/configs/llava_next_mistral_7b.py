"""llava-next-mistral-7b [vlm] — hf:llava-hf/llava-v1.6-mistral-7b-hf
(unverified tier).

Mistral-7B backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
SwiGLU, rope_theta=1e6.  The anyres vision tower is a stub per the
assignment: ``input_specs`` supplies 2880 precomputed patch embeddings
(base 576 + 4 tiles x 576) prepended to the token embeddings.
"""

from repro.configs.registry import ArchMeta
from repro.models.config import ModelConfig

META = ArchMeta(train_microbatches=2,
                source="hf:llava-hf/llava-v1.6-mistral-7b-hf")


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=32000, activation="swiglu", rope_theta=1e6,
        frontend="vision_stub", n_patches=2880,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="llava-tiny", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=223, activation="swiglu",
        frontend="vision_stub", n_patches=8, dtype="float32")
