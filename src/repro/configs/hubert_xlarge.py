"""hubert-xlarge [audio] — arXiv:2106.07447 (unverified tier).

48L d_model=1280 16H (kv=16) head_dim=80 d_ff=5120 vocab=504 (k-means
units); encoder-only (bidirectional, no decode step).  The wav2vec2-style
conv frontend is a stub: ``input_specs`` supplies precomputed frame
embeddings [B, S, d_model].
"""

from repro.configs.registry import ArchMeta
from repro.models.config import ModelConfig

META = ArchMeta(train_microbatches=1, source="arXiv:2106.07447")


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
        d_ff=5120, vocab=504, activation="gelu", causal=False,
        frontend="audio_stub",
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="hubert-tiny", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=97, activation="gelu", causal=False,
        frontend="audio_stub", dtype="float32")
