"""Architecture registry: ``get(arch_id)`` -> (ModelConfig, ArchMeta).

One module per assigned architecture lives next to this file; each exports
``config()`` (the exact published configuration), ``tiny()`` (a reduced
same-family config for CPU smoke tests) and ``META`` (per-arch run
parameters: train microbatch count etc.).
"""

from __future__ import annotations

import dataclasses
import importlib


@dataclasses.dataclass(frozen=True)
class ArchMeta:
    train_microbatches: int = 1      # grad-accumulation steps at train_4k
    source: str = ""


ARCHS = [
    "llava_next_mistral_7b",
    "grok_1_314b",
    "phi35_moe_42b",
    "recurrentgemma_2b",
    "gemma_7b",
    "yi_6b",
    "llama3_405b",
    "qwen15_110b",
    "xlstm_125m",
    "hubert_xlarge",
]

# CLI ids (dashes) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def _mod(arch: str):
    arch = ALIASES.get(arch, arch)
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{arch}")


def get(arch: str):
    m = _mod(arch)
    return m.config(), m.META


def get_tiny(arch: str):
    m = _mod(arch)
    return m.tiny()
