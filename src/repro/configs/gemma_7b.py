"""gemma-7b [dense] — arXiv:2403.08295 (hf tier).

28L d_model=3072 16H (kv=16, MHA) head_dim=256 d_ff=24576 (GeGLU)
vocab=256000; embeddings scaled by sqrt(d) and tied.
"""

from repro.configs.registry import ArchMeta
from repro.models.config import ModelConfig

META = ArchMeta(train_microbatches=2, source="arXiv:2403.08295")


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
        d_ff=24576, vocab=256000, activation="geglu",
        emb_scale=True, tie_embeddings=True,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b-tiny", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=401, activation="geglu", emb_scale=True,
        tie_embeddings=True, dtype="float32")
