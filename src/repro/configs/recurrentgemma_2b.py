"""recurrentgemma-2b [hybrid] — arXiv:2402.19427 (hf tier).

26L d_model=2560 10H (GQA kv=1, MQA) head_dim=256 d_ff=7680 (GeGLU)
vocab=256000; block pattern (RG-LRU, RG-LRU, local-attn) with a 2048-token
attention window; embeddings scaled by sqrt(d) and tied.  Sub-quadratic =>
runs the long_500k cell (constant-state recurrence + ring-buffered window).
"""

from repro.configs.registry import ArchMeta
from repro.models.config import ModelConfig

META = ArchMeta(train_microbatches=1, source="arXiv:2402.19427")


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
        d_ff=7680, vocab=256000, activation="geglu",
        block_pattern=("rglru", "rglru", "local_attn"),
        local_window=2048, rnn_width=2560, conv_width=4,
        emb_scale=True, tie_embeddings=True, rope_theta=10_000.0,
        scan_layers=False,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-tiny", family="hybrid",
        n_layers=3, d_model=96, n_heads=4, n_kv_heads=1, head_dim=24,
        d_ff=192, vocab=307, activation="geglu",
        block_pattern=("rglru", "rglru", "local_attn"),
        local_window=16, rnn_width=96, conv_width=4,
        emb_scale=True, tie_embeddings=True, scan_layers=False,
        dtype="float32")
