"""phi3.5-moe-42b-a6.6b [moe] — hf:microsoft/Phi-3.5-MoE-instruct (hf tier).

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16 experts top-2,
SwiGLU experts.  16 experts divide the 16-way data axis, so this arch is the
expert-parallel hillclimb candidate.
"""

from repro.configs.registry import ArchMeta
from repro.models.config import ModelConfig

META = ArchMeta(train_microbatches=4,
                source="hf:microsoft/Phi-3.5-MoE-instruct")


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=6400, vocab=32064, activation="swiglu",
        n_experts=16, top_k=2, param_dtype="bfloat16",
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-tiny", family="moe",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab=211, activation="swiglu", n_experts=8, top_k=2,
        dtype="float32")
