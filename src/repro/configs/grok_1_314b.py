"""grok-1-314b [moe] — hf:xai-org/grok-1 (unverified tier).

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8 experts
top-2, GeGLU experts, tanh logit soft-cap 30.
"""

from repro.configs.registry import ArchMeta
from repro.models.config import ModelConfig

META = ArchMeta(train_microbatches=8, source="hf:xai-org/grok-1")


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=32768, vocab=131072, activation="geglu",
        n_experts=8, top_k=2, logits_softcap=30.0,
        param_dtype="bfloat16", seq_parallel=True,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="grok-1-tiny", family="moe",
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=499, activation="geglu", n_experts=4, top_k=2,
        logits_softcap=30.0, dtype="float32")
