"""qwen1.5-110b [dense] — hf:Qwen/Qwen1.5-110B family (hf tier).

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064, SwiGLU, QKV bias.
"""

from repro.configs.registry import ArchMeta
from repro.models.config import ModelConfig

META = ArchMeta(train_microbatches=8, source="hf:Qwen/Qwen1.5-110B")


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=49152, vocab=152064, activation="swiglu", qkv_bias=True,
        rope_theta=1e6, param_dtype="bfloat16", seq_parallel=True,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-tiny", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=160, vocab=269, activation="swiglu", qkv_bias=True,
        dtype="float32")
