"""xlstm-125m [ssm] — arXiv:2405.04517 (unverified tier).

12L d_model=768 4H head_dim=192 d_ff=0 vocab=50304; alternating
mLSTM / sLSTM blocks (the mLSTM block carries its own gated projection, the
sLSTM block a 4/3-factor GeGLU FFN).  Constant-state recurrence =>
runs the long_500k cell.
"""

from repro.configs.registry import ArchMeta
from repro.models.config import ModelConfig

META = ArchMeta(train_microbatches=1, source="arXiv:2405.04517")


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
        d_ff=0, vocab=50304, block_pattern=("mlstm", "slstm"),
        scan_layers=False,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="xlstm-tiny", family="ssm",
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=0, vocab=191, block_pattern=("mlstm", "slstm"),
        scan_layers=False, dtype="float32")
