"""yi-6b [dense] — arXiv:2403.04652 (hf tier).

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000, llama-style SwiGLU,
rope_theta=5e6.
"""

from repro.configs.registry import ArchMeta
from repro.models.config import ModelConfig

META = ArchMeta(train_microbatches=2, source="arXiv:2403.04652")


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=11008, vocab=64000, activation="swiglu", rope_theta=5e6,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="yi-6b-tiny", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=160, vocab=251, activation="swiglu", rope_theta=5e6,
        dtype="float32")
