"""llama3-405b [dense] — arXiv:2407.21783 (unverified tier).

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256, SwiGLU,
rope_theta=500k.  Memory notes (256-chip pod): bf16 params + bf16 Adam
moments + bf16 grad accumulation + sequence-parallel residuals are required
to fit 16 GB/chip (DESIGN.md §6).
"""

from repro.configs.registry import ArchMeta
from repro.models.config import ModelConfig

# M=4 (was 16): sequence-parallel residuals shard the remat-saved layer
# inputs 16-way, so activation memory allows 4x fewer microbatches =>
# 4x less per-micro FSDP weight-regather + grad reduce-scatter traffic
# (EXPERIMENTS.md §Perf iteration 3b).
META = ArchMeta(train_microbatches=4, source="arXiv:2407.21783")


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
        d_ff=53248, vocab=128256, activation="swiglu", rope_theta=500_000.0,
        param_dtype="bfloat16", opt_state_dtype="bfloat16",
        grad_accum_dtype="bfloat16", seq_parallel=True,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b-tiny", family="dense",
        n_layers=3, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=503, activation="swiglu", rope_theta=500_000.0,
        dtype="float32")
