"""Heterogeneous-replica dispatch — the paper's big/little cores, verbatim,
at serving-fleet scale.

A fleet mixes fast replicas (latest-gen pods: "big cores") and slow ones
(older-gen / partially-degraded pods: "little cores"; e.g. a v5e pod next
to a v4 pod, or a pod running with a failed host).  Every request needs one
replica slot — the replica pool is the lock.

* ``fair``   — round-robin over replicas (MCS analogue): slow-replica
  service time lands on the critical path of 1/k of requests =>
  fleet throughput collapse (Implication 1).
* ``fast-only`` — never dispatch to slow replicas: queue blows up once the
  fast replicas saturate (the paper's "only big cores" strawman; its
  Bench-5 shows little cores help at lower contention).
* ``asl``    — requests stand by for a fast replica during an AIMD reorder
  window tuned against the request latency SLO; when the window expires
  (fast replicas busy and the SLO is at risk) they take a slow replica.
  Low load => everything runs fast; high load => slow replicas absorb
  exactly as much spill as the SLO allows.

Key-aware variants (the ``ks_*`` device policies' fleet analogues;
docs/workloads.md §Key-sharded traffic): every request carries a
Zipf-drawn key bucketed to ``bucket = key % n_buckets``; the *owner*
replica of a bucket is ``fleet[bucket % n_replicas]`` with the fleet
ordered fast-first, so hot buckets (low ids — the bucketing is
rank-preserving) are owned by fast replicas.

* ``key-erew`` — strict EREW sharding: a request is served ONLY by its
  bucket's owner (earliest request whose owner is free dispatches).
* ``key-crew`` — CREW: reads go to any free replica (fast preferred),
  writes are owner-exclusive.
* ``key-jbsq`` — bounded JBSQ(k): the FIFO head goes to the
  least-loaded free replica (fewest dispatches), ignoring ownership —
  the fairness-first contrast.

The key/write streams are counter-pure (``STREAM_KEY``/``STREAM_RW``
blocks, prefix-invariant in the arrival count) and are only drawn for
key-aware policies, so every other policy is bit-identical to the
pre-keyshard simulator.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core import stats
from repro.core.aimd import AIMDWindow, unit_for
from repro.core.policies import dispatch_names
from repro.faults import host as flt_host
from repro.faults.model import FaultSpec
from repro.workloads import keys as wl_keys
from repro.workloads import traces as wl_traces
from repro.workloads.generators import (LEGACY_LOGNORMAL_CV,
                                        LEGACY_LOGNORMAL_MEAN, STREAM_KEY,
                                        STREAM_RW, ArrivalSpec, ServiceSpec,
                                        uniform_block)

# Fleet-dispatch policy names, keyed off the lock-policy registry (each
# LockPolicy's host_dispatch: fifo -> "fair" round-robin, tas
# big-affinity -> "fast-only", libasl -> "asl" window spill).
DISPATCH_POLICIES = dispatch_names()


@dataclasses.dataclass
class Replica:
    speed: float          # service-time multiplier (1.0 = fast)
    busy_until: float = 0.0
    idx: int = 0          # fleet-wide index (fault-stream namespace)
    served: int = 0       # dispatches so far (fault-draw counter)


def spill_index(queue, clock):
    """Which queued request an ASL spill hands to a free slow replica:
    the earliest-*deadline* expired standby (paper §3.2 — reorder-window
    expiry order, not FIFO arrival order), or None when no window has
    expired yet.  ``queue`` holds ``(arrival_t, service_s, win_deadline,
    timeout_deadline, tries)`` rows (the last two are the resilience
    columns; the window deadline is still ``row[2]``)."""
    expired = [(row[2], i) for i, row in enumerate(queue)
               if clock >= row[2]]
    return min(expired)[1] if expired else None


def simulate_dispatch(policy: str, *, n_fast=4, n_slow=4, slow_factor=3.0,
                      rate_rps=30.0, service_s=0.1, duration_s=300.0,
                      slo=None, pct=99.0, seed=0,
                      default_window=0.02, max_window=30.0,
                      arrival: ArrivalSpec = None,
                      service: ServiceSpec = None, trace=None,
                      timeout_s=None, max_retries=0,
                      backoff_base=0.05, backoff_cap=2.0,
                      admit_cap=None, faults: FaultSpec = None,
                      n_buckets=64, n_keys=1024, zipf_theta=0.99,
                      write_frac=0.5):
    """Event-driven M/G/k with heterogeneous servers; returns metrics.

    ASL: a queued request may wait (stand by) for a fast replica until its
    window expires, then accepts any replica.  Feedback: AIMD on completed
    request latency vs SLO (one shared epoch class).

    The workload comes from ``repro.workloads``: pass a recorded
    ``trace`` to replay it exactly, or ``arrival``/``service`` specs to
    generate one (default: open-loop Poisson arrivals + the legacy
    lognormal service shape) — deterministic per ``seed``.

    Resilience + chaos (docs/faults.md; all off by default, in which
    case the run is bit-identical to the pre-chaos sim):

    * ``timeout_s`` — a request still queued ``timeout_s`` after arrival
      is cancelled; with retries left it re-enqueues after a capped
      exponential backoff (``backoff_base * 2**tries``, cap
      ``backoff_cap``), keeping its original arrival time so measured
      latency includes every backoff.
    * ``admit_cap`` — admission control: arrivals are shed while the
      queue holds that many requests.
    * ``faults`` — a :class:`repro.faults.FaultSpec`: replica outages
      (churn: a replica accepts no new work during "off" slots),
      straggler service spikes, and preemption stalls, all counter-pure
      per (replica, dispatch index) via ``repro.faults.host``.

    Key-aware policies (``key-erew``/``key-crew``/``key-jbsq``) draw a
    Zipf(``n_keys``, ``zipf_theta``) key and a read/write bit per
    request (``write_frac`` = write probability) and bucket keys to
    ``key % n_buckets``; other policies never draw the streams (their
    runs are bit-identical with the knobs at any value).
    """
    if policy not in DISPATCH_POLICIES:
        raise ValueError(f"unknown dispatch policy {policy!r}; "
                         f"registered: {DISPATCH_POLICIES}")
    if trace is None:
        trace = wl_traces.generate(
            arrival or ArrivalSpec("poisson", rate_rps),
            service or ServiceSpec("lognormal",
                                   mean=service_s * LEGACY_LOGNORMAL_MEAN,
                                   cv=LEGACY_LOGNORMAL_CV),
            duration_s, seed)
    fast = [Replica(1.0, idx=i) for i in range(n_fast)]
    slow = [Replica(slow_factor, idx=n_fast + i) for i in range(n_slow)]
    fleet = fast + slow            # fast-first: hot buckets own fast
    n_rep = len(fleet)
    win = AIMDWindow(window=default_window,
                     unit=unit_for(default_window, pct), pct=pct,
                     max_window=max_window)
    arrivals = list(zip(trace.arrival_t.tolist(),
                        trace.service_s.tolist()))
    keyed = policy.startswith("key-")
    if keyed and arrivals:
        # Counter-pure key + read/write streams, prefix-invariant in the
        # arrival count — the device engine's epoch-draw composition
        # (uniform -> Zipf rank -> bucket) at fleet scale.
        n_arr = len(arrivals)
        th, ze, et, al = wl_keys.zipf_consts(max(int(n_keys), 1),
                                             zipf_theta)
        ranks = np.asarray(wl_keys.zipf_key(
            uniform_block(seed, STREAM_KEY, n_arr).astype(np.float32),
            n_keys, th, ze, et, al))
        bks = (ranks % max(int(n_buckets), 1)).tolist()
        wrs = (uniform_block(seed, STREAM_RW, n_arr)
               < write_frac).tolist()
    else:
        bks = [0] * len(arrivals)
        wrs = [False] * len(arrivals)
    arrivals = [(t, s, b, w)
                for (t, s), b, w in zip(arrivals, bks, wrs)]

    chaos_faults = faults if faults is not None and faults.active else None
    if chaos_faults is not None:
        # Precomputed counter-pure schedules (repro.faults.host): per-
        # (replica, slot) outages; per-(replica, dispatch) spike/stall.
        out_mask = flt_host.outage_mask(chaos_faults, n_rep,
                                        duration_s * 4 + 60.0, seed)
        cap_disp = len(arrivals) * (1 + max_retries) + 64
        spikes = [flt_host.spike_hits(chaos_faults, r, cap_disp, seed)
                  for r in range(n_rep)]
        stalls = [flt_host.preempt_stalls(chaos_faults, r, cap_disp, seed)
                  for r in range(n_rep)]

    def rep_out(r, now):
        if chaos_faults is None or chaos_faults.churn_rate <= 0.0:
            return False
        k = min(int(now / chaos_faults.churn_period),
                out_mask.shape[1] - 1)
        return bool(out_mask[r.idx, k])

    lat = []
    served_fast = served_slow = 0
    timeouts = retried = drops = lost = 0
    queue = []    # (arrival_t, svc, win_dead, timeout_dead, tries,
    #               bucket, write) — the last two are the key columns
    #               (0/False for non-key policies)
    events = []         # completion heap
    retry_q = []        # (due_t, seq, arrival_t, svc, tries, bucket, wr)
    seq = 0
    clock = 0.0
    ai = 0
    hard_stop = 10.0 * duration_s + 60.0   # churn_rate=1 can strand work

    def free_replica(pool, now):
        for r in pool:
            if r.busy_until <= now and not rep_out(r, now):
                return r
        return None

    while ai < len(arrivals) or queue or events or retry_q:
        # next event time: arrival, completion, retry release; an ASL
        # window deadline is only an event if a slow replica is free to
        # accept the spill; a queued timeout and (under churn) the next
        # outage-slot boundary are events too.
        t_arr = arrivals[ai][0] if ai < len(arrivals) else np.inf
        t_done = events[0] if events else np.inf
        t_retry = retry_q[0][0] if retry_q else np.inf
        t_next = min(t_arr, t_done, t_retry)
        if policy == "asl" and queue and \
                free_replica(slow, clock) is not None:
            t_dead = min(row[2] for row in queue)
            t_next = min(t_next, max(t_dead, clock))
        if timeout_s is not None and queue:
            t_to = min(row[3] for row in queue)
            t_next = min(t_next, max(t_to, clock))
        if chaos_faults is not None and chaos_faults.churn_rate > 0.0 \
                and queue:
            k = int(clock / chaos_faults.churn_period)
            t_next = min(t_next, (k + 1) * chaos_faults.churn_period)
        if t_next == np.inf:
            break
        clock = max(clock, t_next)
        if clock > hard_stop:
            break
        while events and events[0] <= clock:
            heapq.heappop(events)
        while retry_q and retry_q[0][0] <= clock:
            _, _, a0, svc, tries, bk, wr = heapq.heappop(retry_q)
            queue.append((a0, svc, clock + win.window,
                          clock + timeout_s, tries, bk, wr))
        while ai < len(arrivals) and arrivals[ai][0] <= clock:
            a, svc, bk, wr = arrivals[ai]
            ai += 1
            if admit_cap is not None and len(queue) >= admit_cap:
                drops += 1           # admission control: shed at arrival
                continue
            queue.append((a, svc, a + win.window,
                          (a + timeout_s) if timeout_s is not None
                          else np.inf, 0, bk, wr))
        if timeout_s is not None:
            # Timeout detection: cancel expired queue entries; with
            # retries left they re-arrive after a capped exp backoff.
            keep = []
            for row in queue:
                if clock >= row[3]:
                    timeouts += 1
                    if row[4] < max_retries:
                        retried += 1
                        backoff = min(backoff_base * 2 ** row[4],
                                      backoff_cap)
                        seq += 1
                        heapq.heappush(retry_q,
                                       (clock + backoff, seq, row[0],
                                        row[1], row[4] + 1, row[5],
                                        row[6]))
                    else:
                        lost += 1
                else:
                    keep.append(row)
            queue = keep
        # dispatch loop
        progressed = True
        while queue and progressed:
            progressed = False
            rf = free_replica(fast, clock)
            rs = free_replica(slow, clock)
            target = None
            pick = 0
            if policy == "fair":
                # round-robin: earliest-free replica of either kind
                cands = [r for r in fast + slow
                         if r.busy_until <= clock and not rep_out(r, clock)]
                if cands:
                    target = cands[(served_fast + served_slow)
                                   % len(cands)]
            elif policy == "fast-only":
                target = rf
            elif policy == "key-erew":
                # Strict EREW sharding: the earliest queued request
                # whose bucket-owner replica is free dispatches to it;
                # everyone else waits for their owner.
                for i, row in enumerate(queue):
                    r = fleet[row[5] % n_rep]
                    if r.busy_until <= clock and not rep_out(r, clock):
                        pick, target = i, r
                        break
            elif policy == "key-crew":
                # CREW: reads take any free replica (fast preferred);
                # writes are owner-exclusive.
                for i, row in enumerate(queue):
                    if row[6]:
                        r = fleet[row[5] % n_rep]
                        if r.busy_until <= clock \
                                and not rep_out(r, clock):
                            pick, target = i, r
                            break
                    elif rf is not None or rs is not None:
                        pick = i
                        target = rf if rf is not None else rs
                        break
            elif policy == "key-jbsq":
                # JBSQ-style: the FIFO head joins the least-loaded
                # free replica (fewest dispatches), ownership-blind —
                # the fairness-first contrast to key-erew.
                cands = [r for r in fleet if r.busy_until <= clock
                         and not rep_out(r, clock)]
                if cands:
                    target = min(cands,
                                 key=lambda r: (r.served, r.idx))
            else:  # asl
                if rf is not None:
                    target = rf    # fast replica: FIFO head takes it
                elif rs is not None:
                    i = spill_index(queue, clock)
                    if i is not None:
                        pick = i
                        target = rs
            if target is not None:
                a, svc, dead, to_dead, tries, bk, wr = queue[pick]
                queue.pop(pick)
                dur = svc * target.speed
                if chaos_faults is not None:
                    # Straggle spike first, preemption stall on top —
                    # the device sim's grant() composition order.
                    d_ix = min(target.served, cap_disp - 1)
                    if spikes[target.idx][d_ix]:
                        dur *= chaos_faults.straggle_scale
                    dur += stalls[target.idx][d_ix]
                target.served += 1
                target.busy_until = clock + dur
                heapq.heappush(events, clock + dur)
                latency = clock + dur - a
                lat.append(latency)
                if slo is not None and policy == "asl":
                    win.update(latency, slo)
                if target.speed == 1.0:
                    served_fast += 1
                else:
                    served_slow += 1
                progressed = True

    # Throughput counts every completion; the latency sample alone drops a
    # 5% warmup prefix (counting after the trim undercounted throughput by
    # exactly that warmup fraction).
    completed = len(lat)
    full_lat = lat
    # Zero completions -> nan percentiles (repro.core.stats), not the
    # old [inf] sentinel that leaked inf p50/p99 into reports.
    lat = np.array(lat[int(0.05 * len(lat)):], float)
    good = int(np.sum(np.asarray(full_lat) <= slo)) \
        if slo is not None else None
    return {
        "policy": policy,
        "n": len(lat),
        "completed": completed,
        "throughput_rps": completed / max(clock, 1e-9),
        "p50": stats.percentile(lat, 50),
        "p99": stats.percentile(lat, 99),
        "served_fast": served_fast,
        "served_slow": served_slow,
        "final_window": win.window,
        "slo_violation": (float(np.mean(lat > slo)) if lat.size
                          else float("nan")) if slo else None,
        # resilience counters + goodput (SLO-met completions per second)
        "timeouts": timeouts,
        "retries": retried,
        "drops": drops,
        "lost": lost,
        "goodput_rps": float(good / max(clock, 1e-9))
        if good is not None else None,
    }
