"""Heterogeneous-replica dispatch — the paper's big/little cores, verbatim,
at serving-fleet scale.

A fleet mixes fast replicas (latest-gen pods: "big cores") and slow ones
(older-gen / partially-degraded pods: "little cores"; e.g. a v5e pod next
to a v4 pod, or a pod running with a failed host).  Every request needs one
replica slot — the replica pool is the lock.

* ``fair``   — round-robin over replicas (MCS analogue): slow-replica
  service time lands on the critical path of 1/k of requests =>
  fleet throughput collapse (Implication 1).
* ``fast-only`` — never dispatch to slow replicas: queue blows up once the
  fast replicas saturate (the paper's "only big cores" strawman; its
  Bench-5 shows little cores help at lower contention).
* ``asl``    — requests stand by for a fast replica during an AIMD reorder
  window tuned against the request latency SLO; when the window expires
  (fast replicas busy and the SLO is at risk) they take a slow replica.
  Low load => everything runs fast; high load => slow replicas absorb
  exactly as much spill as the SLO allows.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core.aimd import AIMDWindow, unit_for
from repro.core.policies import dispatch_names
from repro.workloads import traces as wl_traces
from repro.workloads.generators import (LEGACY_LOGNORMAL_CV,
                                        LEGACY_LOGNORMAL_MEAN, ArrivalSpec,
                                        ServiceSpec)

# Fleet-dispatch policy names, keyed off the lock-policy registry (each
# LockPolicy's host_dispatch: fifo -> "fair" round-robin, tas
# big-affinity -> "fast-only", libasl -> "asl" window spill).
DISPATCH_POLICIES = dispatch_names()


@dataclasses.dataclass
class Replica:
    speed: float          # service-time multiplier (1.0 = fast)
    busy_until: float = 0.0


def spill_index(queue, clock):
    """Which queued request an ASL spill hands to a free slow replica:
    the earliest-*deadline* expired standby (paper §3.2 — reorder-window
    expiry order, not FIFO arrival order), or None when no window has
    expired yet.  ``queue`` holds (arrival_t, service_s, deadline) rows."""
    expired = [(d, i) for i, (_, _, d) in enumerate(queue) if clock >= d]
    return min(expired)[1] if expired else None


def simulate_dispatch(policy: str, *, n_fast=4, n_slow=4, slow_factor=3.0,
                      rate_rps=30.0, service_s=0.1, duration_s=300.0,
                      slo=None, pct=99.0, seed=0,
                      default_window=0.02, max_window=30.0,
                      arrival: ArrivalSpec = None,
                      service: ServiceSpec = None, trace=None):
    """Event-driven M/G/k with heterogeneous servers; returns metrics.

    ASL: a queued request may wait (stand by) for a fast replica until its
    window expires, then accepts any replica.  Feedback: AIMD on completed
    request latency vs SLO (one shared epoch class).

    The workload comes from ``repro.workloads``: pass a recorded
    ``trace`` to replay it exactly, or ``arrival``/``service`` specs to
    generate one (default: open-loop Poisson arrivals + the legacy
    lognormal service shape) — deterministic per ``seed``.
    """
    if policy not in DISPATCH_POLICIES:
        raise ValueError(f"unknown dispatch policy {policy!r}; "
                         f"registered: {DISPATCH_POLICIES}")
    if trace is None:
        trace = wl_traces.generate(
            arrival or ArrivalSpec("poisson", rate_rps),
            service or ServiceSpec("lognormal",
                                   mean=service_s * LEGACY_LOGNORMAL_MEAN,
                                   cv=LEGACY_LOGNORMAL_CV),
            duration_s, seed)
    fast = [Replica(1.0) for _ in range(n_fast)]
    slow = [Replica(slow_factor) for _ in range(n_slow)]
    win = AIMDWindow(window=default_window,
                     unit=unit_for(default_window, pct), pct=pct,
                     max_window=max_window)
    arrivals = list(zip(trace.arrival_t.tolist(),
                        trace.service_s.tolist()))

    lat = []
    served_fast = served_slow = 0
    queue = []          # (arrival_t, svc, deadline_for_fast)
    events = []         # completion heap
    clock = 0.0
    ai = 0

    def free_replica(pool, now):
        for r in pool:
            if r.busy_until <= now:
                return r
        return None

    while ai < len(arrivals) or queue or events:
        # next event time: arrival or completion; an ASL window deadline is
        # only an event if a slow replica is free to accept the spill.
        t_arr = arrivals[ai][0] if ai < len(arrivals) else np.inf
        t_done = events[0] if events else np.inf
        t_next = min(t_arr, t_done)
        if policy == "asl" and queue and \
                free_replica(slow, clock) is not None:
            t_dead = min(d for _, _, d in queue)
            t_next = min(t_next, max(t_dead, clock))
        if t_next == np.inf:
            break
        clock = max(clock, t_next)
        while events and events[0] <= clock:
            heapq.heappop(events)
        while ai < len(arrivals) and arrivals[ai][0] <= clock:
            a, svc = arrivals[ai]
            ai += 1
            queue.append((a, svc, a + win.window))
        # dispatch loop
        progressed = True
        while queue and progressed:
            progressed = False
            rf = free_replica(fast, clock)
            rs = free_replica(slow, clock)
            target = None
            pick = 0
            if policy == "fair":
                # round-robin: earliest-free replica of either kind
                cands = [r for r in fast + slow if r.busy_until <= clock]
                if cands:
                    target = cands[(served_fast + served_slow)
                                   % len(cands)]
            elif policy == "fast-only":
                target = rf
            else:  # asl
                if rf is not None:
                    target = rf    # fast replica: FIFO head takes it
                elif rs is not None:
                    i = spill_index(queue, clock)
                    if i is not None:
                        pick = i
                        target = rs
            if target is not None:
                a, svc, dead = queue[pick]
                queue.pop(pick)
                dur = svc * target.speed
                target.busy_until = clock + dur
                heapq.heappush(events, clock + dur)
                latency = clock + dur - a
                lat.append(latency)
                if slo is not None and policy == "asl":
                    win.update(latency, slo)
                if target.speed == 1.0:
                    served_fast += 1
                else:
                    served_slow += 1
                progressed = True

    # Throughput counts every completion; the latency sample alone drops a
    # 5% warmup prefix (counting after the trim undercounted throughput by
    # exactly that warmup fraction).
    completed = len(lat)
    lat = np.array(lat[int(0.05 * len(lat)):] or [np.inf])
    return {
        "policy": policy,
        "n": len(lat),
        "completed": completed,
        "throughput_rps": completed / max(clock, 1e-9),
        "p50": float(np.percentile(lat, 50)),
        "p99": float(np.percentile(lat, 99)),
        "served_fast": served_fast,
        "served_slow": served_slow,
        "final_window": win.window,
        "slo_violation": float(np.mean(lat > slo)) if slo else None,
    }
