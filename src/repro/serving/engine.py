"""Continuous-batching serving engine with pluggable admission scheduling.

The engine runs a loop of *engine slots* (the paper's critical sections):
each slot executes either one batched **decode** micro-step (one token for
every running sequence — short, throughput-dense: the "big core" class) or
one **prefill chunk** (long, latency-elastic: the "little core" class).
Which one runs is the scheduler's lock ordering:

* ``fifo``    — arrival order (MCS): a long prefill head-of-line blocks all
  running decodes => inter-token latency + token throughput collapse.
* ``greedy``  — decode-first always (TAS big-affinity): TTFT collapse /
  prefill starvation under load.
* ``asl``     — the paper: decode admits immediately; prefill chunks are
  standby competitors with an AIMD reorder window tuned against the
  request TTFT SLO (epoch = submit -> first token).

Two clock modes:

* **real**: drives jitted prefill/decode steps of an actual model
  (examples/serve_slo.py uses a tiny config);
* **simulated**: a calibrated cost model advances a virtual clock — used by
  the serving benchmarks for deterministic, load-controlled comparisons
  (the 1-CPU container cannot sustain real concurrent load).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import defaultdict

import numpy as np

from repro.core import stats
from repro.core.asl_schedule import SCHEDULERS


@dataclasses.dataclass
class Request:
    rid: int
    arrival_t: float
    prompt_len: int
    max_new_tokens: int
    slo_ttft: float                 # epoch SLO (submit -> first token)
    epoch_id: int = 0               # SLO class
    # lifecycle
    prefill_done: int = 0
    generated: int = 0
    first_token_t: float | None = None
    finish_t: float | None = None
    # resilience lifecycle (engine timeout/retry/admission — all inert
    # unless the engine was built with the chaos kwargs)
    deadline_t: float = float("inf")
    retries: int = 0
    dropped: bool = False
    timed_out: bool = False


@dataclasses.dataclass
class CostModel:
    """Slot durations (seconds). Calibrated per arch from the roofline
    terms (memory-bound decode, compute-bound prefill)."""

    decode_step_s: float = 2e-3         # one token for the whole batch
    prefill_chunk_s: float = 12e-3      # one chunk of prefill_chunk tokens
    prefill_chunk: int = 2048
    max_batch: int = 64


class ServingEngine:
    """``timeout_s`` / ``max_retries`` / ``backoff_base_s`` /
    ``backoff_cap_s`` / ``admit_limit`` are the resilience knobs
    (docs/faults.md §Serving): a request whose TTFT deadline
    (arrival + timeout_s) passes before its first token is cancelled at
    dequeue and retried after a capped exponential backoff (restarting
    its prefill), up to ``max_retries`` times; ``admit_limit`` is
    admission control — arrivals are shed outright while more than that
    many requests are pending + running (load past saturation).  All
    default off, in which case behavior is bit-identical to the
    pre-chaos engine."""

    def __init__(self, scheduler: str = "asl", cost: CostModel = None,
                 *, scheduler_kwargs: dict = None, seed: int = 0,
                 timeout_s: float = None, max_retries: int = 0,
                 backoff_base_s: float = 0.05, backoff_cap_s: float = 2.0,
                 admit_limit: int = None):
        self.cost = cost or CostModel()
        self.clock = 0.0
        kw = dict(scheduler_kwargs or {})
        # Scheduler names come from the lock-policy registry (each
        # LockPolicy's host_scheduler — repro.core.asl_schedule).
        if scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r}; "
                             f"registered: {sorted(SCHEDULERS)}")
        self.sched = SCHEDULERS[scheduler](clock=lambda: self.clock, **kw)
        self.sched_name = scheduler
        self.running: list[Request] = []      # decode set
        self.done: list[Request] = []
        self.itl_samples: list[float] = []    # inter-token gaps (decode)
        self._last_decode_t: float | None = None
        self._rid = itertools.count()
        # Resilience knobs + per-class (epoch_id) fault counters.
        self.timeout_s = timeout_s
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.admit_limit = admit_limit
        self.timeouts: dict[int, int] = defaultdict(int)
        self.retry_counts: dict[int, int] = defaultdict(int)
        self.drops: dict[int, int] = defaultdict(int)
        self.shed: list[Request] = []         # admission-dropped
        self.expired: list[Request] = []      # timed out, retries spent
        self._retry_q: list = []              # (due_t, seq, Request)
        self._retry_seq = itertools.count()
        # ``seed`` is kept for API compatibility; all workload randomness
        # now lives in repro.workloads (counter-based, engine-independent).
        del seed

    # ------------------------------------------------------------------
    def submit(self, prompt_len: int, max_new_tokens: int, slo_ttft: float,
               epoch_id: int = 0, arrival_t: float = None) -> Request:
        r = Request(next(self._rid),
                    self.clock if arrival_t is None else arrival_t,
                    prompt_len, max_new_tokens, slo_ttft, epoch_id)
        if self.admit_limit is not None and \
                self.sched.pending() + len(self.running) >= self.admit_limit:
            # Admission control: past saturation, shedding at arrival
            # keeps the queue (and every admitted request's wait) bounded.
            r.dropped = True
            self.drops[epoch_id] += 1
            self.shed.append(r)
            return r
        if self.timeout_s is not None:
            r.deadline_t = r.arrival_t + self.timeout_s
        self.sched.submit(r, klass="little", epoch_id=epoch_id)
        return r

    # -- resilience helpers --------------------------------------------
    def retry_pending(self) -> int:
        """Requests waiting out a retry backoff (drivers must not skip
        the clock past them when the queue is otherwise empty)."""
        return len(self._retry_q)

    def _release_retries(self):
        while self._retry_q and self._retry_q[0][0] <= self.clock:
            _, _, r = heapq.heappop(self._retry_q)
            r.deadline_t = self.clock + self.timeout_s
            self.sched.submit(r, klass="little", epoch_id=r.epoch_id)

    def _expired(self, r: Request) -> bool:
        return self.timeout_s is not None and r.first_token_t is None \
            and self.clock > r.deadline_t

    def _on_timeout(self, r: Request):
        self.timeouts[r.epoch_id] += 1
        if r.retries < self.max_retries:
            r.retries += 1
            self.retry_counts[r.epoch_id] += 1
            backoff = min(self.backoff_base_s * 2 ** (r.retries - 1),
                          self.backoff_cap_s)
            r.prefill_done = 0        # a retried request restarts prefill
            heapq.heappush(self._retry_q,
                           (self.clock + backoff, next(self._retry_seq), r))
        else:
            r.timed_out = True
            self.expired.append(r)

    def _admit_decode_slot(self):
        """Decode work is 'big': register one slot-claim per loop if any
        sequence is running (lock_immediately)."""
        if self.running:
            self.sched.submit(None, klass="big")

    # ------------------------------------------------------------------
    def step(self) -> str:
        """Run one engine slot; returns what ran ('decode'/'prefill'/'idle')."""
        if self._retry_q:
            self._release_retries()
        self._admit_decode_slot()
        item = self.sched.next_item()
        # Timeout detection happens at dequeue (the scheduler is a
        # pluggable black box): expired prefill work is cancelled and
        # handed to the retry/expire path, and the slot goes to the next
        # item — the engine never burns a slot on a dead request.
        while item is not None and item.klass == "little" \
                and self._expired(item.payload):
            self._on_timeout(item.payload)
            item = self.sched.next_item()
        if item is None:
            if self._retry_q:
                # Nothing runnable until the next backoff elapses.
                self.clock = max(self.clock, self._retry_q[0][0])
                self._release_retries()
            else:
                self.clock += 1e-4
            return "idle"

        if item.klass == "big":
            self._run_decode()
            return "decode"
        self._run_prefill_chunk(item.payload)
        return "prefill"

    def _run_decode(self):
        if self._last_decode_t is not None and self.running:
            self.itl_samples.append(self.clock - self._last_decode_t)
        self.clock += self.cost.decode_step_s
        self._last_decode_t = self.clock
        # One pass: partition finished/still-running in place (the old
        # copy + .remove() pattern was O(batch^2) per decode step).
        still: list[Request] = []
        for r in self.running:
            r.generated += 1
            if r.first_token_t is None:
                r.first_token_t = self.clock
                self.sched.observe_epoch(
                    r.epoch_id, self.clock - r.arrival_t, r.slo_ttft)
            if r.generated >= r.max_new_tokens:
                r.finish_t = self.clock
                self.done.append(r)
            else:
                still.append(r)
        self.running = still

    def _run_prefill_chunk(self, r: Request):
        self.clock += self.cost.prefill_chunk_s
        r.prefill_done += self.cost.prefill_chunk
        if r.prefill_done >= r.prompt_len:
            if len(self.running) < self.cost.max_batch:
                self.running.append(r)
            else:
                # batch full: decode capacity is the bottleneck; requeue the
                # *admission to the decode set* as immediate work.
                self.running.append(r)   # simple model: allow overfill
        else:
            # Re-submit the remaining chunks.  Paper semantics: an epoch may
            # contain many lock acquisitions, each taking the epoch's
            # *current* reorder window (Algorithm 3 line 7-8) — so every
            # chunk is a fresh lock_reorder with the AIMD-tuned window.
            self.sched.submit(r, klass="little", epoch_id=r.epoch_id)

    # ------------------------------------------------------------------
    def run(self, until_t: float = None, until_done: int = None,
            max_slots: int = 2_000_000):
        for _ in range(max_slots):
            if until_t is not None and self.clock >= until_t:
                break
            if until_done is not None and len(self.done) >= until_done:
                break
            if self.step() == "idle" and not self.sched.pending() \
                    and not self.running and until_done is not None:
                break
        return self

    # ------------------------------------------------------------------
    def _fault_counters(self) -> dict:
        return {
            "timeouts": dict(self.timeouts),
            "retries": dict(self.retry_counts),
            "drops": dict(self.drops),
            "timeouts_total": sum(self.timeouts.values()),
            "retries_total": sum(self.retry_counts.values()),
            "drops_total": sum(self.drops.values()),
        }

    def metrics(self, warmup_frac: float = 0.1) -> dict:
        reqs = [r for r in self.done if r.first_token_t is not None]
        reqs = reqs[int(len(reqs) * warmup_frac):]
        if not reqs:
            return {"n": 0, **self._fault_counters()}
        ttft = np.array([r.first_token_t - r.arrival_t for r in reqs])
        e2e = np.array([r.finish_t - r.arrival_t for r in reqs])
        toks = sum(r.generated for r in reqs)
        span = max(r.finish_t for r in reqs) - min(r.arrival_t for r in reqs)
        viol = np.mean([t > r.slo_ttft for t, r in zip(ttft, reqs)])
        # No ITL samples (e.g. every request shed before a second token)
        # -> nan percentiles below, not the old 0.0 sentinel.
        itl = np.array(self.itl_samples[int(len(self.itl_samples)
                                            * warmup_frac):], float)
        # Goodput: completions that met their TTFT SLO — shed, expired
        # and SLO-late requests all count against it (the chaos figures'
        # useful-work-per-second metric).
        good = [r for t, r in zip(ttft, reqs) if t <= r.slo_ttft]
        offered = len(reqs) + len(self.shed) + len(self.expired)
        return {
            "n": len(reqs),
            "throughput_tok_s": toks / max(span, 1e-9),
            "ttft_p50": stats.percentile(ttft, 50),
            "ttft_p99": stats.percentile(ttft, 99),
            "e2e_p99": stats.percentile(e2e, 99),
            "itl_p50": stats.percentile(itl, 50),
            "itl_p99": stats.percentile(itl, 99),
            "slo_violation_rate": float(viol),
            "goodput_req_s": len(good) / max(span, 1e-9),
            "goodput_tok_s": sum(r.generated for r in good)
            / max(span, 1e-9),
            "goodput_frac": len(good) / max(offered, 1),
            **self._fault_counters(),
        }


def replay_workload(engine: ServingEngine, trace, *, slo_ttft: float = None,
                    duration_s: float = None):
    """Drive the engine from a ``repro.workloads.traces.Trace`` —
    deterministic: the same trace always produces the same run.

    Request shape comes from the trace's ``prompt_len``/``new_tokens``
    columns; class ids become scheduler ``epoch_id``s and per-class SLOs
    (``trace.slo``) the TTFT SLOs (``slo_ttft`` overrides for
    single-class traces).  ``trace.service_s`` is NOT consumed here —
    engine timing comes from the CostModel and the shape columns (the
    dispatch sim is the consumer that replays service times)."""
    if duration_s is None:
        duration_s = float(trace.meta.get(
            "duration", trace.arrival_t[-1] if len(trace) else 0.0))
    pl = trace.cols["prompt_len"]
    nt = trace.cols["new_tokens"]
    slos = trace.slo
    ai = 0
    n = len(trace)
    while engine.clock < duration_s:
        while ai < n and trace.arrival_t[ai] <= engine.clock:
            k = int(trace.klass[ai])
            slo = slo_ttft if slo_ttft is not None else (
                float(slos[k]) if slos is not None else float("inf"))
            engine.submit(int(pl[ai]), int(nt[ai]), slo, epoch_id=k,
                          arrival_t=float(trace.arrival_t[ai]))
            ai += 1
        if ai < n and not engine.sched.pending() and not engine.running \
                and not engine.retry_pending():
            engine.clock = float(trace.arrival_t[ai])  # skip idle gaps
            continue
        engine.step()
    return engine


def poisson_workload(engine: ServingEngine, *, rate_rps: float,
                     duration_s: float, prompt_lens, new_tokens,
                     slo_ttft: float, seed: int = 0, trace=None):
    """Drive the engine with an open-loop Poisson arrival process.

    The workload is materialized as a ``repro.workloads`` trace
    (counter-based draws — deterministic per seed) and replayed; pass
    ``trace`` to replay a recorded one instead."""
    from repro.workloads import traces as wl_traces
    from repro.workloads.generators import ArrivalSpec, ServiceSpec
    if trace is None:
        trace = wl_traces.generate(
            ArrivalSpec("poisson", rate_rps), ServiceSpec(), duration_s,
            seed, cols=wl_traces.request_columns(prompt_lens, new_tokens))
    return replay_workload(engine, trace, slo_ttft=slo_ttft,
                           duration_s=duration_s)


def closed_loop_workload(engine: ServingEngine, *, n_clients: int,
                         think_s: float, duration_s: float, prompt_lens,
                         new_tokens, slo_ttft: float, seed: int = 0):
    """Closed-loop driver: each of ``n_clients`` resubmits one request an
    Exp(``think_s``) think time after its previous one *finishes* (load
    self-throttles with congestion, unlike the open-loop Poisson driver).
    Think draws are counter-based per (client, request index)."""
    from repro.workloads.generators import choice, client_think_gaps
    cap = max(int(duration_s / max(think_s, 1e-6) * 2) + 16, 64)
    gaps = [client_think_gaps(seed, c, cap) * think_s
            for c in range(n_clients)]
    pls = choice(prompt_lens, n_clients * cap, seed)
    nts = choice(new_tokens, n_clients * cap, seed + 1)
    next_t = [float(gaps[c][0]) for c in range(n_clients)]
    n_sub = [1] * n_clients                   # next gap index per client
    subs = [0] * n_clients                    # submissions per client
    inflight: dict[int, int] = {}             # rid -> client
    done_seen = 0
    while engine.clock < duration_s:
        for c in range(n_clients):
            if next_t[c] <= engine.clock:
                # Shape draws are indexed per (client, submission) — a
                # global counter would make the workload depend on
                # completion interleaving (i.e. on the policy under
                # test), breaking the identical-workload discipline.
                i = c * cap + subs[c]
                r = engine.submit(int(pls[i % len(pls)]),
                                  int(nts[i % len(nts)]), slo_ttft,
                                  arrival_t=next_t[c])
                subs[c] += 1
                inflight[r.rid] = c
                next_t[c] = float("inf")
        if not engine.sched.pending() and not engine.running \
                and not engine.retry_pending():
            t_min = min((t for t in next_t if t < float("inf")),
                        default=None)
            if t_min is None or t_min >= duration_s:
                break
            engine.clock = max(engine.clock, t_min)
            continue
        engine.step()
        while done_seen < len(engine.done):
            r = engine.done[done_seen]
            done_seen += 1
            c = inflight.pop(r.rid, None)
            if c is not None and n_sub[c] < cap:
                next_t[c] = r.finish_t + float(gaps[c][n_sub[c]])
                n_sub[c] += 1
    return engine
