"""The fault model: counter-pure draws shared by the device-side lock
simulator and the host-side serving sims.

Three fault classes, each the paper's symmetry assumption broken a
different way (docs/faults.md):

* **Lock-holder preemption** — the holder is descheduled mid-critical-
  section for an Exp-distributed stall; every waiter eats it (the
  classic preemption pathology scalable queue locks are famous for).
* **Core churn** — cores leave and rejoin on a slotted schedule: during
  an "off" slot a core's acquire attempts bounce to the next slot
  boundary (the ROADMAP's cores-joining/leaving-mid-run scenario).
* **Straggler spikes** — a critical section occasionally runs ``scale``x
  long (DVFS throttling / migration turning a big core slow mid-run).

RNG discipline is the same load-bearing invariant as the workload
generators: every draw is pure in ``(seed, stream, *indices)`` —
preemption/straggle index by the core's critical-section counter, churn
by the time slot — so batched, sharded, chunked and single runs see
identical faults, and a zero rate is *bit-identical* to fault-free (the
draw compares ``u < 0`` and every fault term is an additive ``where``).
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.workloads import generators as wlg


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Host-level fault knobs (seconds) for the serving sims — the
    analogue of the ``SimConfig`` fault fields (microseconds).

    ``preempt`` hits a request's *service* (a stall added on the
    replica, mean ``preempt_scale`` seconds), ``churn`` takes whole
    replicas out for ``churn_period``-second slots, ``straggle``
    multiplies a service time by ``straggle_scale``.
    """

    preempt_rate: float = 0.0     # P(stall) per dispatch
    preempt_scale: float = 0.0    # mean stall (seconds)
    churn_rate: float = 0.0       # P(replica out) per period slot
    churn_period: float = 1.0     # outage slot length (seconds)
    straggle_rate: float = 0.0    # P(service spike) per dispatch
    straggle_scale: float = 1.0   # spike multiplier (>= 1)

    def __post_init__(self):
        for f in ("preempt_rate", "churn_rate", "straggle_rate"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0 or math.isnan(v):
                raise ValueError(f"{f} must be a probability, got {v!r}")
        if self.preempt_scale < 0.0 or math.isnan(self.preempt_scale):
            raise ValueError(f"preempt_scale must be >= 0, "
                             f"got {self.preempt_scale!r}")
        if self.churn_period <= 0.0 or math.isnan(self.churn_period):
            raise ValueError(f"churn_period must be > 0, "
                             f"got {self.churn_period!r}")
        if self.straggle_scale < 1.0 or math.isnan(self.straggle_scale):
            raise ValueError(f"straggle_scale must be >= 1, "
                             f"got {self.straggle_scale!r}")

    @property
    def active(self) -> bool:
        return (self.preempt_rate > 0.0 or self.churn_rate > 0.0
                or self.straggle_rate > 0.0)


# --------------------------------------------------------------------------
# Device-side draws (traced; called from simlock's event handlers)
# --------------------------------------------------------------------------

def preempt_extra(seed, core, cs_ix, rate, scale_ticks):
    """Holder-preemption stall (ticks, i32) for core ``core``'s
    ``cs_ix``-th critical section: Exp(mean ``scale_ticks``) with
    probability ``rate``, else 0.  Pure in (seed, core, cs_ix)."""
    u = wlg.counter_uniform(wlg.stream_key(seed, wlg.STREAM_PREEMPT),
                            core, cs_ix)
    uz = wlg.counter_uniform(
        wlg.stream_key(seed, wlg.STREAM_PREEMPT ^ 0x40000), core, cs_ix)
    stall = (scale_ticks * wlg.exp_unit(uz)).astype(jnp.int32)
    return jnp.where(u < rate, stall, 0)


def straggle_extra(seed, core, cs_ix, dur, rate, scale):
    """Straggler service spike: extra ticks that stretch this critical
    section to ``scale`` x its drawn duration, with probability
    ``rate``.  Additive (``dur + extra``) so a zero rate is bit-exact."""
    u = wlg.counter_uniform(wlg.stream_key(seed, wlg.STREAM_SPIKE),
                            core, cs_ix)
    extra = (dur.astype(jnp.float32) * (scale - 1.0)).astype(jnp.int32)
    return jnp.where(u < rate, extra, 0)


def churn_off(seed, core, t, rate, period_ticks):
    """Is ``core`` churned out during the slot containing tick ``t``?
    One decision per (core, slot) — pure, so re-attempts within a slot
    agree and the host can reconstruct the schedule."""
    slot = t // period_ticks
    u = wlg.counter_uniform(wlg.stream_key(seed, wlg.STREAM_CHURN),
                            core, slot)
    return u < rate


def churn_rejoin(t, period_ticks):
    """First tick of the next churn slot (strictly > t, so a bounced
    core always re-fires — churn can never deadlock the sim)."""
    return (t // period_ticks + 1) * period_ticks
