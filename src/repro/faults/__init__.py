"""Fault injection for the lock + serving simulators (docs/faults.md).

Device side: :func:`preempt_extra` / :func:`straggle_extra` /
:func:`churn_off` ride inside simlock's traced event handlers (the
fault knobs are ``SimConfig`` fields, swept as batch axes).  Host side:
:class:`FaultSpec` plus the precomputed schedules in
:mod:`repro.faults.host` drive the serving/dispatch sims.
"""

from repro.core.columns import ColumnSpec, register_column
from repro.faults.host import outage_mask, preempt_stalls, spike_hits
from repro.faults.model import (FaultSpec, churn_off, churn_rejoin,
                                preempt_extra, straggle_extra)

# Per-core fault eligibility rides as an owned SimTables column
# (repro.core.columns): 1.0 = faults may hit this core, padded with
# eligible.  Sweepable table axis (name kept: ``fault_mask``).
register_column(ColumnSpec(
    name="ft_mask", dtype="f32", default=1.0, field="fault_mask",
    owner="faults",
    doc="per-core fault eligibility (0/1); multiplies the fault rates"))

__all__ = [
    "FaultSpec",
    "churn_off",
    "churn_rejoin",
    "outage_mask",
    "preempt_extra",
    "preempt_stalls",
    "spike_hits",
    "straggle_extra",
]
