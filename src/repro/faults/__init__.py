"""Fault injection for the lock + serving simulators (docs/faults.md).

Device side: :func:`preempt_extra` / :func:`straggle_extra` /
:func:`churn_off` ride inside simlock's traced event handlers (the
fault knobs are ``SimConfig`` fields, swept as batch axes).  Host side:
:class:`FaultSpec` plus the precomputed schedules in
:mod:`repro.faults.host` drive the serving/dispatch sims.
"""

from repro.faults.host import outage_mask, preempt_stalls, spike_hits
from repro.faults.model import (FaultSpec, churn_off, churn_rejoin,
                                preempt_extra, straggle_extra)

__all__ = [
    "FaultSpec",
    "churn_off",
    "churn_rejoin",
    "outage_mask",
    "preempt_extra",
    "preempt_stalls",
    "spike_hits",
    "straggle_extra",
]
