"""Host-side fault schedules for the serving sims (dispatch / engine).

Everything is precomputed into numpy arrays from the same counter-pure
streams the device simulator draws from — a per-event jnp dispatch in
the dispatch sim's Python event loop would be ~orders slower, and the
precomputed schedule is exactly reconstructible (same (seed, entity,
index) counters) regardless of horizon or interleaving.
"""

from __future__ import annotations

import numpy as np

from repro.faults.model import FaultSpec
from repro.workloads import generators as wlg


def outage_mask(spec: FaultSpec, n_replicas: int, duration: float,
                seed: int) -> np.ndarray:
    """bool[n_replicas, n_slots]: replica r is out during slot k.
    Slot k covers [k*churn_period, (k+1)*churn_period)."""
    n_slots = int(np.ceil(max(duration, 0.0) / spec.churn_period)) + 2
    if spec.churn_rate <= 0.0:
        return np.zeros((n_replicas, n_slots), bool)
    return np.stack([
        wlg.straggle_uniforms(seed, r, n_slots, stream=wlg.STREAM_CHURN)
        < spec.churn_rate for r in range(n_replicas)])


def spike_hits(spec: FaultSpec, replica: int, n: int,
               seed: int) -> np.ndarray:
    """bool[n]: dispatch i on ``replica`` is a straggler spike."""
    if spec.straggle_rate <= 0.0:
        return np.zeros(n, bool)
    u = wlg.straggle_uniforms(seed, replica, n, stream=wlg.STREAM_SPIKE)
    return u < spec.straggle_rate


def preempt_stalls(spec: FaultSpec, replica: int, n: int,
                   seed: int) -> np.ndarray:
    """f64[n]: preemption stall (seconds) paid by dispatch i on
    ``replica`` — Exp(mean preempt_scale) with prob preempt_rate."""
    if spec.preempt_rate <= 0.0:
        return np.zeros(n)
    u = wlg.straggle_uniforms(seed, replica, n,
                              stream=wlg.STREAM_PREEMPT)
    uz = wlg.straggle_uniforms(seed, replica, n,
                               stream=wlg.STREAM_PREEMPT ^ 0x40000)
    stall = spec.preempt_scale * -np.log1p(-uz)
    return np.where(u < spec.preempt_rate, stall, 0.0)
