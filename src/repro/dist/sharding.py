"""Logical-axis sharding rules (GSPMD) for the model stack.

Model code never names mesh axes.  Every parameter/activation carries
*logical* axis names (``PSpec.axes`` in schemas, ``constrain(x, ...)`` on
activations); a :class:`Rules` table maps logical axes onto mesh axes for
the current (mesh, step-kind) cell.  Outside a ``use_mesh`` context every
constraint is the identity, so single-host tests and CPU smoke runs pay
nothing and need no mesh.

Shape-aware degradation: a logical axis whose dim is not divisible by the
mesh-axis size (tiny test configs, ragged vocab) silently degrades to
replicated instead of failing GSPMD — the dry-run records what actually
sharded via the compiled memory analysis.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = threading.local()


@dataclasses.dataclass(frozen=True)
class Rules:
    """Mapping: logical axis name -> mesh axis (str | tuple | None)."""

    mesh: object                    # jax Mesh (or None: rules-only tests)
    table: dict

    def _axis_size(self, mesh_axes) -> int:
        if self.mesh is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        return math.prod(self.mesh.shape[a] for a in mesh_axes)

    def num_shards(self, axis: str) -> int:
        """How many ways logical ``axis`` splits under this table (1 when
        unmapped or the mesh is absent)."""
        m = self.table.get(axis)
        return 1 if m is None else self._axis_size(m)

    def spec(self, axes, shape=None) -> P:
        """PartitionSpec for logical ``axes`` (shape-aware, no axis reuse)."""
        used: set = set()
        out = []
        for i, ax in enumerate(axes):
            m = self.table.get(ax)
            if m is None:
                out.append(None)
                continue
            names = (m,) if isinstance(m, str) else tuple(m)
            if any(n in used for n in names):
                out.append(None)
                continue
            if shape is not None and shape[i] % self._axis_size(names):
                out.append(None)        # non-divisible -> replicate
                continue
            used.update(names)
            out.append(names[0] if len(names) == 1 else names)
        while out and out[-1] is None:  # trailing Nones are implicit
            out.pop()
        return P(*out)


def build_rules(mesh, *, kv_heads: int = 0, n_experts: int = 0,
                step: str = "train", seq_parallel: bool = False,
                expert_parallel: bool = False) -> Rules:
    """Default logical->mesh table for one (mesh, step-kind) cell.

    * ``data`` (plus ``pod`` when present) shards the token batch — except
      at decode, which runs weight-stationary (batch replicated; the MoE
      layer keys off ``table["batch"] is None`` to pick that path).
    * ``model`` shards heads / ff / experts / vocab (tensor parallel).
    """
    axes = set(mesh.axis_names) if mesh is not None else set()
    data = tuple(a for a in ("pod", "data") if a in axes) or None
    if isinstance(data, tuple) and len(data) == 1:
        data = data[0]
    model = "model" if "model" in axes else None
    batch = None if step == "decode" else data
    table = {
        "batch": batch,
        "cache_batch": data,
        "q_heads": model,
        "kv_heads": model if kv_heads == 0 or kv_heads > 1 else None,
        "ff": model,
        "vocab": model,
        "experts": ("data" if expert_parallel and "data" in axes
                    else model) if n_experts else None,
        "seq_res": model if seq_parallel else None,
        # replicated everywhere:
        "embed": None, "act_embed": None, "head_dim": None, "norm": None,
        "seq": None, "kv_seq": None, "moe_cap": None, "rnn": None,
    }
    return Rules(mesh=mesh, table=table)


def build_sweep_rules(mesh, data_axis="data") -> Rules:
    """Logical->mesh table for the batched simulator sweep.

    One logical axis matters: ``cells`` — the sweep's leading cell
    dimension.  It maps onto ``data_axis`` (a mesh axis name or tuple of
    names; axes absent from the mesh are dropped), everything per-cell
    stays replicated.  The same shape-aware degradation as the model
    rules applies: a cell count not divisible by the mesh slice degrades
    to replicated rather than failing GSPMD — callers that must shard
    (``simlock.sweep``) pad the cell axis to the next multiple of
    :meth:`Rules.num_shards` first.
    """
    axes = set(mesh.axis_names) if mesh is not None else set()
    names = (data_axis,) if isinstance(data_axis, str) else tuple(data_axis)
    present = tuple(a for a in names if a in axes)
    cells = None if not present else \
        (present[0] if len(present) == 1 else present)
    return Rules(mesh=mesh, table={"cells": cells})


def row_splits(n_rows: int, n_shards: int) -> list:
    """Contiguous per-shard row counts for ``n_rows`` tiled over
    ``n_shards`` (GSPMD equal-block tiling; requires divisibility)."""
    if n_shards <= 0 or n_rows % n_shards:
        raise ValueError(f"{n_rows} rows do not tile over {n_shards} shards")
    return [n_rows // n_shards] * n_shards


def current_rules() -> Rules | None:
    """The Rules installed by the innermost ``use_mesh`` (None outside)."""
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_mesh(mesh, rules: Rules = None):
    """Activate (mesh, rules) for model code in this thread."""
    if rules is None:
        rules = build_rules(mesh)
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def constrain(x, *axes):
    """with_sharding_constraint under the active rules (identity if none)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = rules.spec(axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))
