"""Bounded-staleness gradient commits — the paper's lock ordering applied
to data-parallel training.

Mapping (DESIGN.md §3, ROADMAP straggler direction): the serialized commit
of a gradient into the global parameters is the critical section; a pod
that has stepped ahead of the slowest pod is a "little core" whose commit
may be *reordered* (delayed) — but only within a bounded window, so the
slowest pod is never starved and gradient staleness stays bounded
(starvation-freedom <-> bounded quality loss).

* ``window_steps == 0``  -> fully synchronous (lockstep rounds).
* ``window_steps == inf``-> unbounded async.
* in between             -> a pod may run ahead by ``< window`` steps; the
  window itself is AIMD-tuned against a *quality SLO* (staleness penalty
  plays the role of the paper's epoch latency) and capped by
  ``max_window`` (the 100 ms bound analogue -> hard staleness guarantee).
"""

from __future__ import annotations

import threading

import numpy as np

from repro.core import stats
from repro.core.aimd import AIMDWindow, unit_for
from repro.workloads.generators import straggle_uniforms


class BoundedStalenessController:
    """Decides whether pod ``p`` may start (and then commit) its next step.

    ``can_commit(p)`` is true iff p is not ahead of the slowest pod at all,
    or ahead by strictly less than the current window — so after the commit
    its lead is at most ``window`` (<= ``max_window``): a hard staleness
    bound, the analogue of the paper's maximum reorder window.
    """

    def __init__(self, n_pods: int, *, window_steps: float = 0.0,
                 max_window: float = None, pct: float = 99.0):
        self.n_pods = n_pods
        if max_window is None:
            max_window = window_steps
        self.max_window = float(max_window)
        self._aimd = AIMDWindow(
            window=float(window_steps),
            unit=unit_for(float(window_steps), pct),
            pct=pct, max_window=self.max_window)
        self.steps = [0] * n_pods
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    @property
    def window(self) -> float:
        return self._aimd.window

    def can_commit(self, pod: int) -> bool:
        with self._lock:
            ahead = self.steps[pod] - min(self.steps)
            return ahead == 0 or ahead < self._aimd.window

    def commit(self, pod: int):
        with self._lock:
            self.steps[pod] += 1

    def staleness(self) -> int:
        """Current lead of the fastest pod over the slowest (steps)."""
        with self._lock:
            return max(self.steps) - min(self.steps)

    def lead(self, pod: int) -> int:
        """Pod's own lead over the slowest pod — the staleness of the
        gradient this pod just committed."""
        with self._lock:
            return self.steps[pod] - min(self.steps)

    def observe_quality(self, penalty: float, slo: float):
        """AIMD feedback: staleness-induced quality penalty vs. its SLO
        (Algorithm 2 with penalty in place of epoch latency)."""
        with self._lock:
            self._aimd.update(penalty, slo)


def simulate(n_pods: int, durations, *, controller: BoundedStalenessController,
             straggle_prob: float = 0.0, straggle_factor: float = 1.0,
             seed: int = 0, horizon_steps: int = 400,
             quality_slo: float = float("inf"),
             penalty_per_stale: float = 0.0):
    """Event-driven sim of ``n_pods`` data-parallel pods under a commit
    controller.  ``durations[p]`` is pod p's base step time; each step
    independently straggles (x ``straggle_factor``) with ``straggle_prob``
    (preemptions, ECC retries, network blips).

    Returns ``(steps_per_s, mean_staleness, p99_staleness)`` — staleness
    sampled at every commit.

    Straggle draws are counter-based (``repro.workloads.generators``):
    pod ``p``'s step ``k`` straggles iff its uniform — pure in
    ``(seed, p, k)`` — lands under ``straggle_prob``, so the straggler
    pattern is identical across horizons, controllers and commit
    interleavings (no sequential RNG state).
    """
    INF = float("inf")
    t = 0.0
    finish = [INF] * n_pods          # completion time of the in-flight step
    blocked = [False] * n_pods
    staleness_samples: list[int] = []
    commits = 0
    # A pod can start at most one step per global commit, plus its final
    # in-flight step — horizon_steps + 1 draws bound every pod.
    u = [straggle_uniforms(seed, p, horizon_steps + 1)
         for p in range(n_pods)] if straggle_prob > 0.0 else None
    started = [0] * n_pods

    def step_duration(p: int) -> float:
        d = float(durations[p])
        if straggle_prob > 0.0:
            if u[p][started[p]] < straggle_prob:
                d *= straggle_factor
            started[p] += 1
        return d

    def try_start(p: int):
        if controller.can_commit(p):
            blocked[p] = False
            finish[p] = t + step_duration(p)
        else:
            blocked[p] = True
            finish[p] = INF

    for p in range(n_pods):
        try_start(p)

    while commits < horizon_steps:
        p = int(np.argmin(finish))
        if finish[p] == INF:         # total deadlock cannot happen: the
            break                    # slowest pod always has ahead == 0
        t = finish[p]
        controller.commit(p)
        commits += 1
        # Staleness of the committed gradient = this pod's own lead (the
        # global max-min lead would keep penalizing laggards for a sprint
        # the window already ended, collapsing the AIMD loop).
        st = controller.lead(p)
        staleness_samples.append(st)
        if penalty_per_stale > 0.0 or quality_slo != float("inf"):
            controller.observe_quality(st * penalty_per_stale, quality_slo)
        try_start(p)
        for q in range(n_pods):      # a commit may unblock waiting pods
            if blocked[q]:
                try_start(q)

    sps = commits / max(t, 1e-12)
    # Zero commits -> no staleness distribution exists: nan, not a 0.0
    # sentinel that would read as "perfectly fresh" (repro.core.stats
    # is the repo-wide empty-samples convention).
    mean_st = float(np.mean(staleness_samples)) if staleness_samples \
        else float("nan")
    p99_st = stats.percentile(staleness_samples, 99)
    return sps, mean_st, p99_st
