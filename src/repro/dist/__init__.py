"""Distribution substrate: logical-axis sharding rules, bounded-staleness
commit control, and analytic HLO/collective accounting."""
