"""Analytic FLOP/byte models + HLO collective accounting for dry-runs.

Two independent estimates that the dry-run / roofline compare:

* *analytic* — closed-form transformer arithmetic from the config (the
  6ND rule plus attention terms), independent of XLA;
* *measured* — XLA's ``cost_analysis`` and the collective schedule parsed
  out of the compiled HLO text (:func:`collective_stats`).
"""

from __future__ import annotations

import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4,
                "u32": 4, "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%x = f32[256,1024]{1,0} all-reduce(...)` — shape of the collective result.
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

# Group size out of either HLO spelling: iota `[n_groups,size]<=[...]` or
# explicit `{{0,1,...},{...}}` (size = elements of the first group).
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[\d+,(\d+)\]")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")


def _ring_link_bytes(op: str, result_bytes: float, n: int) -> float:
    """Per-device link traffic of one collective under the standard ring
    algorithms, from the HLO *result* shape (all-reduce/all-gather results
    are full-size, reduce-scatter results are the per-device shard)."""
    if op == "collective-permute":
        return result_bytes                      # one hop per device
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return result_bytes * 2.0 * (n - 1) / n  # reduce-scatter + gather
    if op == "reduce-scatter":
        return result_bytes * (n - 1)            # input is n shards
    # all-gather / all-to-all: each device ships (n-1)/n of the result
    return result_bytes * (n - 1) / n


def _param_count(cfg) -> float:
    d, dh = cfg.d_model, cfg.head_dim
    attn = d * dh * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    n_mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
    mlp = n_mats * d * cfg.d_ff
    if cfg.n_experts:
        mlp = cfg.n_experts * mlp + d * cfg.n_experts   # experts + router
    per_layer = attn + mlp + 2 * d
    embed = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return cfg.n_layers * per_layer + embed + d


def _active_param_count(cfg) -> float:
    """Params touched per token (MoE: top_k of n_experts)."""
    if not cfg.n_experts:
        return _param_count(cfg)
    dense = _param_count(cfg)
    n_mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
    expert = n_mats * cfg.d_model * cfg.d_ff
    inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * expert
    return dense - inactive


def analytic_model_flops(cfg, shape) -> float:
    """Estimated *model* FLOPs for one global step/call of ``shape``.

    train: 6·N_active·tokens (fwd+bwd) + attention scores;
    prefill: 2·N·tokens + attention; decode: 2·N·batch (one token each).
    """
    b, s = shape.global_batch, shape.seq_len
    n_act = _active_param_count(cfg)
    attn_layers = sum(1 for k in cfg.blocks() if k in ("attn", "local_attn"))
    if shape.kind == "decode":
        tokens = b                       # one token per sequence
        attn = 4.0 * tokens * s * cfg.attn_q_dim * attn_layers
        return 2.0 * n_act * tokens + attn
    tokens = float(b) * s
    attn = 4.0 * tokens * s * cfg.attn_q_dim * attn_layers
    if cfg.causal:
        attn *= 0.5
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_act * tokens + (3.0 if shape.kind == "train" else 1.0) \
        * attn


def analytic_hbm_bytes(cfg, shape, rules=None) -> float:
    """Minimum HBM traffic per call: parameters once + KV-cache sweep
    (decode) or activations (train/prefill, one residual stream pass).

    Global bytes by default; pass ``rules`` (a ``dist.sharding.Rules``)
    to divide each component by its actual shard count on that mesh —
    per-device traffic, the roofline's memory term.  Weight reads use
    the FULL parameter count, not the top_k-active count: every
    standard shape carries >= n_experts tokens per step, so each device
    streams its whole resident expert shard (gating thins compute, not
    the weight bytes read).
    """
    pbytes = {"float32": 4, "bfloat16": 2, "float16": 2}.get(
        cfg.param_dtype, 4)
    abytes = {"float32": 4, "bfloat16": 2, "float16": 2}.get(cfg.dtype, 2)
    b, s = shape.global_batch, shape.seq_len
    w_sh = c_sh = a_sh = 1
    if rules is not None:
        w_sh = rules.num_shards("ff")            # tensor-parallel weights
        c_sh = (rules.num_shards("cache_batch")
                * rules.num_shards("kv_heads"))  # KV: batch x heads
        a_sh = rules.num_shards("batch")         # activations: data-par
    params = _param_count(cfg) * pbytes / w_sh
    attn_layers = sum(1 for k in cfg.blocks() if k in ("attn", "local_attn"))
    if shape.kind == "decode":
        kv = 2.0 * b * s * cfg.n_kv_heads * cfg.head_dim * abytes \
            * attn_layers / c_sh
        return params + kv + b * cfg.d_model * abytes * cfg.n_layers / a_sh
    acts = float(b) * s * cfg.d_model * abytes * cfg.n_layers / a_sh
    return params * (3 if shape.kind == "train" else 1) + acts


def xla_cost(compiled) -> dict:
    """XLA ``cost_analysis`` as one flat dict, across jax versions (older
    releases return the dict directly, newer ones a one-element list)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def executable_stats(compiled) -> dict:
    """Per-executable accounting: XLA FLOPs/bytes plus the collective
    schedule parsed from the compiled HLO (the dry-run/bench record)."""
    cost = xla_cost(compiled)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collectives": collective_stats(compiled.as_text()),
    }


def collective_stats(hlo_text: str, n_devices: int | None = None) -> dict:
    """Parse the compiled HLO: per-collective op counts and result bytes.

    Returns ``{op: {"count", "bytes", "link_bytes"}}`` per collective kind
    plus flat ``"total_bytes"``/``"total_count"`` rollups and a nested
    ``"total"`` rollup.  ``bytes`` is raw result-shape bytes;
    ``link_bytes`` applies the ring-algorithm factors (2(n-1)/n etc.) with
    the group size parsed from each op's ``replica_groups`` — per-device
    link traffic, the roofline's collective term.  ``n_devices`` is the
    group-size fallback for ops with no parsable ``replica_groups``.
    """
    out = {op: {"count": 0, "bytes": 0.0, "link_bytes": 0.0}
           for op in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        # -start/-done pairs describe one collective; count starts only.
        if m.group(0).rstrip("(").endswith("-done"):
            continue
        nelem = 1
        for d in dims.split(","):
            if d:
                nelem *= int(d)
        rbytes = nelem * _DTYPE_BYTES.get(dtype, 4)
        line = hlo_text[m.start():hlo_text.find("\n", m.end())]
        gm = _IOTA_GROUPS_RE.search(line)
        if gm:
            gsize = int(gm.group(1))
        else:
            gm = _EXPLICIT_GROUPS_RE.search(line)
            gsize = (len(gm.group(1).split(",")) if gm and gm.group(1)
                     else (n_devices or 1))
        out[op]["count"] += 1
        out[op]["bytes"] += rbytes
        out[op]["link_bytes"] += _ring_link_bytes(op, rbytes, gsize)
    out["total_count"] = sum(out[op]["count"] for op in _COLLECTIVES)
    out["total_bytes"] = sum(out[op]["bytes"] for op in _COLLECTIVES)
    out["total"] = {
        "count": out["total_count"], "bytes": out["total_bytes"],
        "link_bytes": sum(out[op]["link_bytes"] for op in _COLLECTIVES)}
    out["ops"] = {op: out[op] for op in _COLLECTIVES if out[op]["count"]}
    return out
