"""The paper's contribution: asymmetry-aware SLO-guided lock ordering.

* :mod:`repro.core.aimd`         — Algorithm 2 (AIMD reorder window), host + jnp.
* :mod:`repro.core.locks`        — FIFO/TAS/ticket/proportional baselines.
* :mod:`repro.core.reorderable`  — Algorithm 1 (reorderable lock).
* :mod:`repro.core.libasl`       — Algorithms 2+3 (epoch API, ASL mutex).
* :mod:`repro.core.policies`     — the pluggable lock-policy registry (the
  simulator's policy ids, host scheduler + dispatch names derive from it).
* :mod:`repro.core.simlock`      — JAX discrete-event AMP simulator (figures).
* :mod:`repro.core.asl_schedule` — the lock ordering as an engine-slot
  admission policy (serving / straggler mitigation).
"""

from repro.core.aimd import AIMDWindow, aimd_update, unit_for
from repro.core.asl_schedule import (ASLScheduler, FIFOScheduler,
                                     GreedyScheduler, SCHEDULERS)
from repro.core.libasl import ASLMutex, LibASL
from repro.core.locks import FIFOLock, ProportionalLock, TASLock, TicketLock
from repro.core.policies import REGISTRY, LockPolicy
from repro.core.reorderable import ReorderableLock

__all__ = [
    "AIMDWindow", "aimd_update", "unit_for", "ASLScheduler",
    "FIFOScheduler", "GreedyScheduler", "SCHEDULERS", "ASLMutex", "LibASL",
    "FIFOLock", "ProportionalLock", "TASLock", "TicketLock",
    "ReorderableLock", "LockPolicy", "REGISTRY",
]
