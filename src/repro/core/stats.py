"""Shared streaming-statistics helpers (host-side, numpy only).

Two things live here:

1. :func:`percentile` — the repo-wide empty-safe percentile.  Every
   host-side metrics path (simlock summaries, serving dispatch/engine,
   staleness, workload clients) funnels through it so "no samples"
   uniformly reports ``nan`` instead of raising or inventing a 0.0/inf
   sentinel.

2. The log-bucketed streaming-histogram layout used by the simulator's
   constant-memory tail metrics (``SimConfig.hist``,
   docs/simulator.md §Streaming metrics).  The device records counts;
   everything value-shaped (edges, representative values, quantiles,
   SLO fractions) is reconstructed here from the three layout numbers
   ``(lo, hi, n_buckets)``.

Bucket layout (``n_buckets = B >= 4``, growth ``g = (hi/lo)**(1/(B-2))``):

    bucket 0      : [0, lo)                  underflow
    bucket j      : [lo*g^(j-1), lo*g^j)     j = 1 .. B-2 (log-spaced)
    bucket B-1    : [hi, inf)                overflow

A sample is bucketed on device with two precomputed scalars
(``log2(lo)`` and ``1/log2(g)`` — see :func:`layout`), so recording is
one log2 + one clipped floor + one scatter-add.  Counts are u32 and
merge across cores, sweep cells, shards and devices by plain addition —
exactly associative and commutative, so any merge order is bitwise
identical.  Quantiles reconstructed from a merged histogram carry a
documented relative-error bound of ``sqrt(g) - 1`` (< one bucket's
relative width ``g - 1``) versus the exact order statistics, for
samples inside ``[lo, hi)``.
"""

from __future__ import annotations

import math

import numpy as np

__all__ = [
    "percentile", "layout", "growth", "edges", "reps",
    "quantile", "good_count", "merge", "rel_err_bound",
]


def percentile(vals, q) -> float:
    """``np.percentile`` that returns ``nan`` on zero samples (and never
    raises on empty input).  ``q`` may be a scalar or a sequence; the
    return shape follows ``np.percentile``."""
    v = np.asarray(vals, float).ravel()
    if v.size == 0:
        q = np.asarray(q, float)
        return float("nan") if q.ndim == 0 else np.full(q.shape, np.nan)
    res = np.percentile(v, q)
    return float(res) if np.ndim(res) == 0 else res


# --------------------------------------------------------------------------
# Log-bucketed histogram layout
# --------------------------------------------------------------------------

def growth(lo: float, hi: float, n_buckets: int) -> float:
    """Per-bucket growth factor g: bucket upper/lower edge ratio."""
    if not (0.0 < lo < hi) or n_buckets < 4:
        raise ValueError(f"need 0 < lo < hi and n_buckets >= 4, got "
                         f"lo={lo!r} hi={hi!r} n_buckets={n_buckets!r}")
    return (hi / lo) ** (1.0 / (n_buckets - 2))


def rel_err_bound(lo: float, hi: float, n_buckets: int) -> float:
    """The documented quantile error bound: one bucket's relative width
    ``g - 1``, for samples in ``[lo, hi)``.  The reconstruction itself
    is tighter (``sqrt(g) - 1``, see :func:`quantile`); the slack
    absorbs float32 device bucketing of samples that sit within
    rounding distance of a bucket edge."""
    return growth(lo, hi, n_buckets) - 1.0


def layout(lo: float, hi: float, n_buckets: int) -> tuple:
    """The two scalars the device bucketing needs:
    ``(log2(lo), 1/log2(g))``.  Bucket index of a sample v is
    ``clip(1 + floor((log2(v) - log2(lo)) / log2(g)), 0, B-1)``."""
    g = growth(lo, hi, n_buckets)
    return math.log2(lo), 1.0 / math.log2(g)


def edges(lo: float, hi: float, n_buckets: int) -> np.ndarray:
    """The ``B-1`` internal bucket boundaries ``lo * g^j``,
    j = 0 .. B-2 (the last equals ``hi`` up to rounding)."""
    g = growth(lo, hi, n_buckets)
    return lo * g ** np.arange(n_buckets - 1, dtype=float)


def reps(lo: float, hi: float, n_buckets: int) -> np.ndarray:
    """Representative value per bucket: the geometric mid of the bucket's
    edges for the log-spaced interior, the nearest finite edge for the
    underflow/overflow buckets (conservative — error there is unbounded
    by construction; choose lo/hi to enclose the data)."""
    e = edges(lo, hi, n_buckets)
    g = growth(lo, hi, n_buckets)
    r = np.empty(n_buckets, float)
    r[0] = e[0]
    r[1:-1] = e[:-1] * math.sqrt(g)   # geometric mid of [e[j-1], e[j])
    r[-1] = e[-1]
    return r


def merge(hists) -> np.ndarray:
    """Merge histograms by summation over every leading axis: accepts a
    ``[..., B]`` array or a sequence of them.  u64 accumulation, so the
    merge is exact, associative and commutative — any cell/shard/device
    order is bitwise identical."""
    if isinstance(hists, (list, tuple)):
        hists = [np.asarray(h, np.uint64).reshape(-1, np.shape(h)[-1])
                 for h in hists]
        hists = np.concatenate(hists, axis=0)
    h = np.asarray(hists, np.uint64)
    return h.reshape(-1, h.shape[-1]).sum(axis=0, dtype=np.uint64)


def quantile(counts, q, lo: float, hi: float) -> float:
    """Quantile from a (merged) histogram; ``nan`` on zero counts.

    Mirrors ``np.percentile``'s linear interpolation at bucket
    resolution: the two order statistics straddling rank
    ``(total-1) * q/100`` are located exactly in the CDF and each is
    replaced by its bucket's representative value (geometric mid).  A
    convex combination preserves a multiplicative bound, so for samples
    inside ``[lo, hi)`` the result is within a factor ``sqrt(g)`` of the
    exact interpolated percentile — relative error <= ``sqrt(g) - 1``,
    strictly less than one bucket's relative width ``g - 1``."""
    c = merge(counts)
    total = int(c.sum())
    if total == 0:
        return float("nan")
    r = reps(lo, hi, c.size)
    k = (total - 1) * float(q) / 100.0
    k_lo = int(math.floor(k))
    w = k - k_lo
    cum = np.cumsum(c)
    # searchsorted over the CDF: first bucket whose cumulative count
    # reaches the (1-indexed) straddling ranks.
    j_lo = int(np.searchsorted(cum, k_lo + 1))
    j_hi = int(np.searchsorted(cum, min(k_lo + 2, total)))
    return float((1.0 - w) * r[j_lo] + w * r[j_hi])


def good_count(counts, thr: float, lo: float, hi: float) -> float:
    """Estimated number of samples <= ``thr`` from a histogram.

    Buckets entirely below the threshold count in full; the bucket
    containing it contributes fractionally by log-interpolation (the
    within-bucket distribution is taken log-uniform, consistent with the
    geometric-mid representative).  Exact when ``thr`` lands on a bucket
    edge; off by at most one bucket's contents otherwise."""
    c = merge(counts).astype(float)
    if thr < 0:
        return 0.0
    e = edges(lo, hi, c.size)
    j = int(np.searchsorted(e, thr, side="right"))  # bucket holding thr
    full = c[:j].sum()                              # buckets entirely <= thr
    if j == 0:
        # thr inside the underflow bucket [0, lo): linear credit.
        return float(c[0] * min(thr / lo, 1.0))
    if j >= c.size - 1:
        return float(full + (c[-1] if thr >= hi else 0.0))
    frac = (math.log(thr) - math.log(e[j - 1])) / \
        (math.log(e[j]) - math.log(e[j - 1]))
    return float(full + c[j] * min(max(frac, 0.0), 1.0))
