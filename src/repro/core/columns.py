"""Registry of policy/feature-owned per-core ``SimTables`` columns.

Any lock policy or feature layer (workloads, faults, energy) can declare
a per-core column that rides traced in ``SimTables.col`` — the per-core
analogue of PR 4's ``SimParams.pol`` / ``SimState.pol`` slots.  A
:class:`ColumnSpec` names the column, its dtype, its neutral default
(used to pad short value tuples — never index-clamp a short table inside
jit), and where its values come from on :class:`SimConfig`: a dedicated
config field (the three migrated built-ins keep theirs for back-compat)
or the generic ``SimConfig.columns`` tuple for plugin-owned columns.

Registration happens at import time of the owning layer
(``repro.faults`` registers ``ft_mask``, ``repro.workloads`` registers
``slo_scale`` + ``wl_service``, ``repro.core.energy`` registers the
DVFS/power columns, a policy module registers its own next to its
``@register``).  ``simlock.build_tables`` materializes every registered
column; sweepable columns become table sweep axes automatically.

This module must stay import-cycle-free: it imports nothing from
``repro`` so the feature packages can register columns while
``repro.core`` is still mid-initialization.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

#: name -> ColumnSpec, in registration order (order is not load-bearing:
#: ``SimTables.col`` is a dict pytree, flattened in sorted-key order).
COLUMNS: dict = {}


@dataclasses.dataclass(frozen=True)
class ColumnSpec:
    """One declared per-core column of ``SimTables.col``."""

    #: column key in ``SimTables.col`` (and the sweep-axis name when the
    #: spec has no dedicated config field).
    name: str
    #: "f32" | "i32" — the traced array dtype.
    dtype: str = "f32"
    #: neutral pad/default value: a config that does not set the column
    #: gets a full-width array of this (and short tuples are padded with
    #: it, never index-clamped).
    default: float = 0.0
    #: ``SimConfig`` field carrying the raw per-core values; ``None`` ->
    #: the values come from the generic ``SimConfig.columns`` tuple.
    field: Optional[str] = None
    #: whether the column is a table sweep axis (rebuilds ``SimTables``
    #: per cell; still one executable).
    sweepable: bool = True
    #: optional raw-entry -> numeric encoder (e.g. a SERVICES name ->
    #: its id); identity when ``None``.
    encode: Optional[Callable] = None
    #: validate raw entries as numbers (NaN / negative rejected at
    #: ``SimConfig`` construction).  ``False`` for encoded columns whose
    #: raw entries are names.
    numeric: bool = True
    #: numeric entries must be strictly positive (e.g. ``dvfs`` divides
    #: segment durations).
    positive: bool = False
    #: the registering layer ("faults", "workloads", "energy", or a
    #: policy name) — documentation + conformance.
    owner: str = ""
    doc: str = ""

    @property
    def axis(self) -> str:
        """Sweep-axis / config-surface name for this column."""
        return self.field or self.name

    def raw_values(self, cfg) -> tuple:
        """The raw (un-encoded, un-padded) per-core values on ``cfg``."""
        if self.field:
            return tuple(getattr(cfg, self.field))
        return tuple(dict(cfg.columns).get(self.name, ()))

    def host_values(self, cfg, n: int) -> tuple:
        """Encoded values padded with the default to ``n`` cores — the
        exact host-side tuple ``build_tables`` materializes."""
        raw = self.raw_values(cfg)
        enc = tuple(self.encode(v) for v in raw) if self.encode else raw
        return (enc + (self.default,) * n)[:n]

    def np_values(self, cfg, n: int):
        """:meth:`host_values` as a numpy array for host-side metric
        paths (e.g. ``simlock.summarize``'s per-core SLO scaling) —
        float64/int64, NOT the traced dtype: host metrics keep full
        precision so padding a column can never move a summary bit."""
        import numpy as np
        return np.asarray(self.host_values(cfg, n),
                          float if self.dtype == "f32" else np.int64)


def register_column(spec: ColumnSpec) -> ColumnSpec:
    """Register a column spec (append-only; duplicate names rejected)."""
    if not spec.name:
        raise ValueError("ColumnSpec needs a name")
    if spec.name in COLUMNS:
        raise ValueError(f"duplicate SimTables column {spec.name!r} "
                         f"(owned by {COLUMNS[spec.name].owner!r})")
    if spec.dtype not in ("f32", "i32"):
        raise ValueError(f"ColumnSpec.dtype must be 'f32'|'i32', "
                         f"got {spec.dtype!r}")
    COLUMNS[spec.name] = spec
    return spec


def lookup(name: str) -> ColumnSpec:
    """Spec by column name, with a did-you-mean on unknown names."""
    try:
        return COLUMNS[name]
    except KeyError:
        import difflib
        hint = difflib.get_close_matches(name, COLUMNS, n=1)
        raise ValueError(
            f"unknown SimTables column {name!r}; registered: "
            f"{sorted(COLUMNS)}"
            + (f" -- did you mean {hint[0]!r}?" if hint else "")) from None


def axis_to_spec() -> dict:
    """Sweep-axis name -> spec, for every sweepable registered column."""
    return {s.axis: s for s in COLUMNS.values() if s.sweepable}
