"""AIMD reorder-window controller — Algorithm 2 of the paper, exactly.

The controller maps a coarse-grained latency SLO onto a fine-grained reorder
window: on an SLO violation the window halves (exponential reduction) and the
additive unit is recomputed as ``window * (100 - PCT) / 100``; every epoch end
adds one unit (linear growth).  With PCT=99 this makes the post-recovery
violation probability ~1% (paper footnote 4), i.e. the P99 latency "barely
meets" the SLO.

Two implementations share the same constants:

* :class:`AIMDWindow` — host-side (used by the threaded LibASL mutex, the
  serving admission scheduler and the bounded-staleness controller).
* :func:`aimd_update` — pure-jnp functional form (used by the JAX
  discrete-event lock simulator; shape-polymorphic so it can be vmapped).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

# Paper defaults. Units are nanoseconds in the paper; the controller is
# unit-agnostic (the simulator uses microseconds, the serving engine seconds).
DEFAULT_WINDOW = 1_000.0
DEFAULT_UNIT = 10.0
MAX_WINDOW = 100_000_000.0  # paper: 100ms upper bound => starvation-free
MIN_WINDOW = 0.0


def unit_for(window: float, pct: float = 99.0) -> float:
    """The additive-increase unit for a window at violation percentile
    ``pct`` — ``window * (100 - pct) / 100`` (paper footnote 4: with
    PCT=99 the post-recovery violation probability is ~1%).  The ONE
    place this formula lives; every consumer (host mutex, admission
    schedulers, fleet dispatch, staleness controller, the simulator's
    traced ``unit0``) derives its unit here."""
    return window * (100.0 - pct) / 100.0


@dataclasses.dataclass
class AIMDWindow:
    """Per-(thread, epoch-id) reorder window state (paper Algorithm 2).

    ``update()`` is called at ``epoch_end`` with the measured epoch latency
    and its SLO; returns the new window.
    """

    window: float = DEFAULT_WINDOW
    unit: float = DEFAULT_UNIT
    pct: float = 99.0
    max_window: float = MAX_WINDOW

    def update(self, latency: float, slo: float) -> float:
        if latency > slo:
            # Exponential reduction (paper line 25-26).
            self.window = self.window / 2.0
            self.unit = unit_for(self.window, self.pct)
        # Linear growth, applied unconditionally (paper line 28).
        self.window = min(self.window + self.unit, self.max_window)
        self.window = max(self.window, MIN_WINDOW)
        return self.window


def aimd_update(window, unit, latency, slo, *, pct=99.0, max_window=MAX_WINDOW):
    """Functional Algorithm 2 step. All args may be jnp arrays (vmap-safe)."""
    violated = latency > slo
    w = jnp.where(violated, window * 0.5, window)
    u = jnp.where(violated, unit_for(w, pct), unit)
    w = jnp.clip(w + u, MIN_WINDOW, max_window)
    return w, u
