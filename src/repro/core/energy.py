"""DVFS + power model for the AMP lock simulator (docs/energy.md).

The paper's premise is that asymmetric multicores exist for *power
efficiency*; this layer adds the watts.  Five registered per-core
``SimTables`` columns (repro.core.columns):

* ``dvfs`` — per-core frequency multiplier (1.0 = nominal).  Applied
  host-side in ``build_tables`` (segment durations divide by it — a
  faster clock shortens both CS and non-CS work) *and* traced, so the
  in-sim dynamic power can scale with f^3 (P_dyn ~ C V^2 f with V ~ f,
  the classic DVFS cube law — Costero 2015, Saez 2024).
* ``p_cs`` / ``p_spin`` / ``p_park`` / ``p_idle`` — per-core power draw
  (watts) while computing (NONCRIT/HOLDER), busy-waiting (SPIN/STANDBY),
  parked in a wait queue (QUEUED), and idle (open-loop ARRIVAL wait /
  inactive padded cores).  The compute and spin draws scale with
  ``dvfs^3``; park/idle are frequency-independent floor draws.

Energy integrates in-sim: each retired event adds ``dt * power(phase)``
per core into the ``SimState.energy`` accumulator (watt-ticks), which
``summarize()`` surfaces as ``energy_j`` / ``power_w`` /
``tput_per_watt`` / ``edp``.  The integration is statically gated on
any power column being set (``simlock._energy_on``): default configs
compile no energy ops and are bit-identical to pre-energy builds.

``BIG_W`` / ``LITTLE_W`` are the default calibration: a big core draws
~4x a little core's active power for ~2-3.75x the speed — littles win
on throughput-per-watt under contention, the big.LITTLE trade the
``energy_efficiency`` figure measures.
"""

from __future__ import annotations

from repro.core.columns import ColumnSpec, register_column

register_column(ColumnSpec(
    name="dvfs", dtype="f32", default=1.0, field="dvfs",
    positive=True, owner="energy",
    doc="per-core frequency multiplier; divides segment durations, "
        "cubes into the active/spin power draw"))
for _name, _doc in (
        ("p_cs", "active (compute/CS) watts, scaled by dvfs^3"),
        ("p_spin", "busy-wait watts, scaled by dvfs^3"),
        ("p_park", "parked-in-queue watts"),
        ("p_idle", "idle watts (also inactive padded cores)")):
    register_column(ColumnSpec(
        name=_name, dtype="f32", default=0.0, field=_name,
        owner="energy", doc=_doc))

#: Default per-class power calibration (watts).  Shaped after published
#: big.LITTLE measurements (Cortex-A15/A7 class): the big core's active
#: draw is ~4x the little's while its speedup is only ~2-3.75x, so
#: littles hold the throughput-per-watt edge.
BIG_W = {"p_cs": 4.0, "p_spin": 1.6, "p_park": 0.4, "p_idle": 0.2}
LITTLE_W = {"p_cs": 1.0, "p_spin": 0.4, "p_park": 0.12, "p_idle": 0.06}

POWER_COLUMNS = ("p_cs", "p_spin", "p_park", "p_idle")


def amp_power(big) -> dict:
    """Per-core power tables from a big/little map: the four power-column
    kwargs (``p_cs``/``p_spin``/``p_park``/``p_idle``) drawn from the
    ``BIG_W``/``LITTLE_W`` calibration — splat into ``SimConfig`` or
    ``simlock.with_columns``."""
    return {k: tuple(BIG_W[k] if b else LITTLE_W[k] for b in big)
            for k in POWER_COLUMNS}
