"""ASL scheduler — the paper's lock ordering as an admission policy.

A continuous-batching inference engine (and a gradient-commit loop) has the
same structure as the paper's critical section: a serialized *engine slot*
that heterogeneous work items compete for.  Mapping (DESIGN.md §3):

* **big core**  -> work the asymmetric system serves cheaply per unit of
  SLO-credit (decode micro-steps; non-straggler pods),
* **little core** -> long, latency-elastic work (prefill chunks; stragglers),
* **lock order** -> which item the next engine slot admits.

Policies (mirroring the paper's baselines):

* ``FIFOScheduler``   — strict arrival order (MCS analogue): prefill
  head-of-line blocks decode => token-throughput collapse.
* ``GreedyScheduler`` — always prefer the "big" class (TAS big-affinity
  analogue): little-class latency collapse / starvation.
* ``ASLScheduler``    — the paper: big class admitted immediately; little
  items are *standby* for a per-class AIMD reorder window (Algorithm 2
  constants, shared via :mod:`repro.core.aimd`).  An item whose window
  expired enters the FIFO queue and cannot be bypassed further (bounded
  reordering => starvation-free).  Work-conserving: when no big work is
  pending, standby items are admitted at once (the paper's
  ``is_lock_free`` fast path).

The scheduler is clock-agnostic (inject ``clock()``) so benchmarks drive it
with a simulated clock and the live serving engine drives it with
``time.monotonic``.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import typing
from collections import deque

from repro.core.aimd import AIMDWindow, unit_for
from repro.core.policies import REGISTRY


@dataclasses.dataclass
class WorkItem:
    """One admission request competing for the engine slot."""

    payload: typing.Any
    klass: str                 # "big" | "little" (or any registered class)
    epoch_id: int = 0          # SLO class (paper epoch id)
    arrival_t: float = 0.0
    deadline_t: float = 0.0    # arrival + reorder window (standby expiry)
    seq: int = 0               # arrival order tiebreak


class SchedulerBase:
    name = "base"

    def __init__(self, clock=None):
        self._clock = clock if clock is not None else _default_clock
        self._seq = itertools.count()

    def submit(self, payload, klass: str, epoch_id: int = 0) -> WorkItem:
        raise NotImplementedError

    def next_item(self) -> typing.Optional[WorkItem]:
        """Admit the next item to the engine slot (None if nothing pending)."""
        raise NotImplementedError

    def observe_epoch(self, epoch_id: int, latency: float, slo: float):
        """Feedback at request completion (epoch_end). Default: no-op."""

    def pending(self) -> int:
        raise NotImplementedError


def _default_clock() -> float:
    import time

    return time.monotonic()


class FIFOScheduler(SchedulerBase):
    """Strict arrival order — the MCS analogue."""

    name = "fifo"

    def __init__(self, clock=None):
        super().__init__(clock)
        self._q: deque[WorkItem] = deque()

    def submit(self, payload, klass, epoch_id=0):
        it = WorkItem(payload, klass, epoch_id, self._clock(), 0.0,
                      next(self._seq))
        self._q.append(it)
        return it

    def next_item(self):
        return self._q.popleft() if self._q else None

    def pending(self):
        return len(self._q)


class GreedyScheduler(SchedulerBase):
    """Always serve the big class first — the TAS big-affinity analogue."""

    name = "greedy"

    def __init__(self, clock=None, big_class: str = "big"):
        super().__init__(clock)
        self._big: deque[WorkItem] = deque()
        self._rest: deque[WorkItem] = deque()
        self._big_class = big_class

    def submit(self, payload, klass, epoch_id=0):
        it = WorkItem(payload, klass, epoch_id, self._clock(), 0.0,
                      next(self._seq))
        (self._big if klass == self._big_class else self._rest).append(it)
        return it

    def next_item(self):
        if self._big:
            return self._big.popleft()
        return self._rest.popleft() if self._rest else None

    def pending(self):
        return len(self._big) + len(self._rest)


class ASLScheduler(SchedulerBase):
    """The paper's SLO-guided bounded reordering as an admission policy.

    ``submit(klass="big")``      == lock_immediately  (FIFO queue)
    ``submit(klass="little")``   == lock_reorder(window[epoch_id])
    ``observe_epoch``            == epoch_end -> AIMD update (Algorithm 2)

    Beyond-paper extensions (each individually switchable, all OFF by
    default so the default object is paper-faithful):

    * ``warm_start``  — initialize a class window from the first observed
      latency headroom instead of the paper's fixed default (cuts the
      convergence transient).
    * ``mi_factor``   — multiplicative *increase* when latency is far below
      the SLO (paper growth is purely linear; this converges faster after
      load drops while keeping AIMD's violation response).
    """

    name = "asl"

    def __init__(self, clock=None, *, pct: float = 99.0,
                 default_window: float = 0.05, max_window: float = 10.0,
                 big_class: str = "big", warm_start: bool = False,
                 mi_factor: float = 0.0, mi_threshold: float = 0.5):
        super().__init__(clock)
        self._fifo: deque[WorkItem] = deque()      # enqueued (unbypassable)
        # Min-heap of (deadline_t, seq, item): promotion pops expired items
        # in expiry order and the work-conserving pop takes the earliest
        # deadline, both O(log n) (the old list was rescanned/re-sorted on
        # every call).
        self._standby: list[tuple] = []
        self._windows: dict[int, AIMDWindow] = {}
        self._pct = pct
        self._default_window = default_window
        self._max_window = max_window
        self._big_class = big_class
        self._warm_start = warm_start
        self._seen: set[int] = set()
        self._mi_factor = mi_factor
        self._mi_threshold = mi_threshold

    # ------------------------------------------------------------------
    def _win(self, epoch_id: int) -> AIMDWindow:
        if epoch_id not in self._windows:
            self._windows[epoch_id] = AIMDWindow(
                window=self._default_window,
                unit=unit_for(self._default_window, self._pct),
                pct=self._pct, max_window=self._max_window)
        return self._windows[epoch_id]

    def window(self, epoch_id: int) -> float:
        return self._win(epoch_id).window

    def submit(self, payload, klass, epoch_id=0):
        now = self._clock()
        # A standby whose window already expired enqueued at its expiry
        # time — it must precede big work submitted after that (the lock's
        # FIFO order once enqueued is inviolable).
        self._promote_expired(now)
        it = WorkItem(payload, klass, epoch_id, now, 0.0, next(self._seq))
        if klass == self._big_class:
            self._fifo.append(it)           # lock_immediately
        else:
            it.deadline_t = now + self._win(epoch_id).window
            heapq.heappush(self._standby,   # lock_reorder(window)
                           (it.deadline_t, it.seq, it))
        return it

    def _promote_expired(self, now: float):
        """Standby items whose reorder window expired enqueue FIFO (Alg.1).
        Heap order == (deadline_t, seq), so items enqueue in expiry order
        (paper: not arrival order — each standby has its own window)."""
        while self._standby and self._standby[0][0] <= now:
            self._fifo.append(heapq.heappop(self._standby)[2])

    def next_item(self):
        now = self._clock()
        self._promote_expired(now)
        if self._fifo:
            return self._fifo.popleft()
        if self._standby:
            # Queue empty -> the slot is free: work-conserving admission
            # (paper: standby enqueues when the waiting queue is empty);
            # earliest deadline first == the old full-sort's head.
            return heapq.heappop(self._standby)[2]
        return None

    def observe_epoch(self, epoch_id, latency, slo):
        w = self._win(epoch_id)
        if self._warm_start and epoch_id not in self._seen:
            self._seen.add(epoch_id)
            if latency < slo:
                # Beyond-paper: jump to the measured headroom.
                w.window = min(max(slo - latency, w.window), w.max_window)
                w.unit = unit_for(w.window, self._pct)
                return
        self._seen.add(epoch_id)
        before = w.window
        w.update(latency, slo)
        if (self._mi_factor > 0.0 and latency <= self._mi_threshold * slo
                and w.window <= before + w.unit + 1e-12):
            # Beyond-paper: multiplicative increase while far under SLO.
            w.window = min(w.window * (1.0 + self._mi_factor), w.max_window)

    def pending(self):
        return len(self._fifo) + len(self._standby)


# Admission-scheduler names are keyed off the lock-policy registry: each
# LockPolicy with a host analogue declares it as ``host_scheduler``
# (fifo -> fifo, tas big-affinity -> greedy, libasl -> asl), so the
# serving engine, benchmarks and the lock simulator agree on one naming
# scheme.  A new lock policy with a host analogue registers its
# scheduler class here.
_IMPL = {
    "fifo": FIFOScheduler,
    "greedy": GreedyScheduler,
    "asl": ASLScheduler,
}
SCHEDULERS = {p.host_scheduler: _IMPL[p.host_scheduler]
              for p in REGISTRY.values() if p.host_scheduler}
