"""LibASL — the paper's policy: big cores enqueue immediately; little
cores stand by for an AIMD-controlled reorder window (Algorithms 1-3).
The AIMD step is the shared :func:`repro.core.aimd.aimd_update` — the
same Algorithm 2 the host-side mutex and schedulers run."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.aimd import aimd_update
from repro.core.policies import register
from repro.core.policies.base import (INF, LockPolicy, QUEUED, STANDBY, deq,
                                      enq, grant, lock_of, lock_vec, park,
                                      qlen, ticks, weighted_pick)


@register
class LibASLPolicy(LockPolicy):
    name = "libasl"
    uses_standby = True
    param_slots = ("slo", "unit0")
    table_slots = ("big", "col.slo_scale")
    state_slots = ("window", "unit", "q", "q_head", "q_tail")
    host_scheduler = "asl"
    host_dispatch = "asl"

    def on_acquire(self, st, cfg, tb, pm, c, t, cond):
        l = lock_of(st, cfg, tb, c)
        is_big = tb.big[c] == 1
        free = st.holder[l] == -1
        q_empty = qlen(st, l, 0) == 0
        can_grab = jnp.logical_and(free, q_empty)
        grab = jnp.logical_and(can_grab, cond)
        # Big cores: lock_immediately == FIFO enqueue. Little: standby.
        wait = jnp.logical_and(jnp.logical_not(can_grab), cond)
        enq_c = jnp.logical_and(wait, is_big)
        standby = jnp.logical_and(wait, jnp.logical_not(is_big))
        st = grant(st, cfg, tb, pm, grab, c, t)
        st = enq(st, enq_c, l, 0, c)
        win = jnp.minimum(st.window[c],
                          ticks(cfg.max_window_us)).astype(jnp.int32)
        new_phase = jnp.where(enq_c, QUEUED,
                              jnp.where(standby, STANDBY, st.phase[c]))
        new_ready = jnp.where(enq_c, INF,
                              jnp.where(standby, t + jnp.maximum(win, 0),
                                        st.t_ready[c]))
        return st._replace(
            phase=st.phase.at[c].set(new_phase),
            t_ready=st.t_ready.at[c].set(new_ready))

    def on_standby_expiry(self, st, cfg, tb, pm, c, t, cond):
        """Reorder window expired -> enqueue FIFO (Alg.1 line 16)."""
        l = lock_of(st, cfg, tb, c)
        free = jnp.logical_and(st.holder[l] == -1, qlen(st, l, 0) == 0)
        grab = jnp.logical_and(free, cond)
        wait = jnp.logical_and(jnp.logical_not(free), cond)
        st = grant(st, cfg, tb, pm, grab, c, t)
        st = enq(st, wait, l, 0, c)
        return park(st, wait, c, QUEUED)

    def on_release(self, st, cfg, tb, pm, c, t, ep_latency, last, cond):
        """Algorithm 2: AIMD the reorder window (little cores only),
        against the per-core class SLO (clients.amp_config)."""
        adjust = jnp.logical_and(jnp.logical_and(last, tb.big[c] == 0),
                                 cond)
        w, u = aimd_update(st.window[c], st.unit[c], ep_latency,
                           pm.slo * tb.col["slo_scale"][c], pct=cfg.pct,
                           max_window=ticks(cfg.max_window_us))
        return st._replace(
            window=st.window.at[c].set(jnp.where(adjust, w, st.window[c])),
            unit=st.unit.at[c].set(jnp.where(adjust, u, st.unit[c])))

    def pick_next(self, st, cfg, tb, pm, l, t, cond):
        # FIFO queue first.
        nonempty = jnp.logical_and(qlen(st, l, 0) > 0, cond)
        st, cq = deq(st, nonempty, l, 0)
        st = grant(st, cfg, tb, pm, nonempty, cq, t, wakeup=True)
        # Queue empty -> a standby competitor may grab the free lock
        # (Algorithm 1: "when the waiting queue is empty").
        standby = jnp.logical_and(st.phase == STANDBY,
                                  lock_vec(st, cfg, tb) == l)
        key, sub = jax.random.split(st.key)
        pick, any_standby = weighted_pick(sub, jnp.where(standby, 1.0, 0.0))
        any_standby = jnp.logical_and(
            jnp.logical_and(jnp.logical_not(nonempty), any_standby), cond)
        st = st._replace(key=jnp.where(cond, key, st.key))
        return grant(st, cfg, tb, pm, any_standby, pick, t)
