"""The lock-policy contract + the shared simulator vocabulary.

A :class:`LockPolicy` plugs into the policy-agnostic event loop of
:mod:`repro.core.simlock` through four hooks:

* ``on_acquire``        — a core's non-critical section ended; decide
  grab / queue / standby / spin (phase NONCRIT fires it).
* ``on_standby_expiry`` — a reorder window expired (phase STANDBY; only
  reachable when ``uses_standby`` is True, which also gates whether the
  handler exists in the compiled HLO at all).
* ``on_release``        — policy-private feedback at a critical-section
  release (e.g. LibASL's AIMD window update); the generic handler has
  already recorded the latencies.
* ``pick_next``         — the holder released; select & grant the next
  holder of lock ``l`` (the caller cleared ``holder[l]``; leaving the
  lock free is a legal outcome).

Every hook is *fully conditional*: it takes a ``cond`` and must commit
no state when it is false — combine ``cond`` only via ``logical_and`` /
``where`` (it may be the Python literal ``True`` on the single-run
``lax.switch`` path).  Hooks must also be **shape-independent**: a
padded (inactive) core must never perturb a decision — use
:func:`weighted_pick` for RNG choices and mask scans with INF/0 so the
batched, padded, sharded and single paths stay bit-identical.

State discipline: a policy *declares* the slots it owns —

* ``param_slots`` / ``table_slots`` name the :class:`SimParams` /
  :class:`SimTables` fields it reads (documentation + conformance);
* ``state_slots`` name entries of the ``SimState.pol`` dict (or core
  ``SimState`` fields) it owns; new per-run state goes into the ``pol``
  dict via :meth:`LockPolicy.init_state`, new traced knobs into the
  ``SimParams.pol`` dict via :meth:`LockPolicy.init_params` (fed from
  ``SimConfig.policy_kw``, canonicalized out of the jit key);
* ``sweep_axes`` maps sweep-axis names onto ``pol`` param slots, so a
  policy knob sweeps like any built-in axis (one executable);
* ``own_columns`` names the per-core ``SimTables.col`` columns the
  policy registered via :func:`repro.core.columns.register_column` —
  declared ``(name, dtype, default, sweepable)`` specs that batch as
  table sweep axes (docs/simulator.md §Policy-owned table columns).

Registration: decorate the class with ``@register`` (see
``repro.core.policies``); the registry order fixes the policy ids.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.faults import model as flt

# Phases == event types (one pending event per core; the phase of the
# core at the head of the event clock selects the handler).
NONCRIT, STANDBY, QUEUED, HOLDER, SPIN, ARRIVAL = 0, 1, 2, 3, 4, 5
INF = jnp.int32(1 << 30)

# 1 tick = 10 ns
US = 100  # ticks per microsecond


def ticks(us: float) -> int:
    return int(round(us * US))


def policy_opts(cfg) -> dict:
    """``SimConfig.policy_kw`` as a dict (policy-owned numeric knobs)."""
    return dict(cfg.policy_kw)


# --------------------------------------------------------------------------
# Queue helpers (ring buffers). All conditional: ops are no-ops when !cond.
# --------------------------------------------------------------------------

def enq(st, cond, l, b, c):
    n = st.q.shape[-1]
    pos = st.q_tail[l, b] % n
    val = jnp.where(cond, c, st.q[l, b, pos])
    q = st.q.at[l, b, pos].set(val)
    q_tail = st.q_tail.at[l, b].add(jnp.where(cond, 1, 0))
    return st._replace(q=q, q_tail=q_tail)


def deq(st, cond, l, b):
    """Returns (st, core) — core = -1 when !cond or empty."""
    n = st.q.shape[-1]
    nonempty = st.q_tail[l, b] > st.q_head[l, b]
    do = jnp.logical_and(cond, nonempty)
    pos = st.q_head[l, b] % n
    c = jnp.where(do, st.q[l, b, pos], -1)
    q_head = st.q_head.at[l, b].add(jnp.where(do, 1, 0))
    return st._replace(q_head=q_head), c


def qlen(st, l, b):
    return st.q_tail[l, b] - st.q_head[l, b]


def weighted_pick(key, weights):
    """Draw an index ~ weights with ONE scalar uniform (shape-independent:
    zero-weight padding entries never win and never perturb the draw, so a
    padded-core run is bit-identical to the unpadded one).  The total is
    cum[-1], NOT jnp.sum: a differently-ordered reduce could land one ulp
    above the cumsum, letting u fall past every threshold and "pick" a
    zero-weight index."""
    cum = jnp.cumsum(weights)
    total = cum[-1]
    u = jax.random.uniform(key) * total
    pick = jnp.argmax(cum > u).astype(jnp.int32)
    return pick, total > 0.0


def lock_of(st, cfg, tb, c):
    """The lock core ``c`` currently contends.  Key-sharded mode
    (``cfg.n_keys > 0`` — a static gate bit after canonicalization)
    reads the per-epoch Zipf-drawn lock (``SimState.cur_lock``, set by
    the engine's epoch-boundary key draws); otherwise the static
    per-segment program lock — the pre-multi-lock expression, so
    key-off runs compile identical HLO (bit-parity by construction)."""
    if cfg.n_keys > 0:
        return st.cur_lock[c]
    return tb.seg_lock[st.seg[c]]


def lock_vec(st, cfg, tb):
    """Per-core effective lock ids as a vector (``i32[N]``) — the
    vectorized :func:`lock_of`, used by waiter-mask scans."""
    if cfg.n_keys > 0:
        return st.cur_lock
    return tb.seg_lock[st.seg]


def grant(st, cfg, tb, pm, cond, c, t, wakeup=False):
    """Make core c (if cond) the holder of its lock; schedule its release.
    ``wakeup=True`` models a blocking lock's parked-waiter handoff latency
    (Bench-6): only queue-pop handoffs pay it, spinners/standbys do not."""
    c_safe = jnp.maximum(c, 0)
    l = lock_of(st, cfg, tb, c_safe)
    dur = tb.cs_dur[c_safe, st.seg[c_safe]]
    if cfg.wl:
        # Current-epoch service multiplier (drawn at the last epoch end);
        # floor at 1 tick so a heavy-tailed draw can't create a 0-length
        # critical section.
        dur = jnp.maximum((dur.astype(jnp.float32)
                           * st.svc_scale[c_safe]).astype(jnp.int32), 1)
    if cfg.straggle_rate > 0.0 or cfg.preempt_rate > 0.0:
        # Fault injection (repro.faults): the draw index is the core's
        # CS counter (counter-pure — batching/chunking/sharding cannot
        # perturb it), the rate is multiplied by the per-core
        # eligibility mask, and both terms are additive wheres, so a
        # zero rate is bit-identical to a fault-free run.
        gix = st.cs_cnt[c_safe]
        eligible = tb.col["ft_mask"][c_safe]
    if cfg.straggle_rate > 0.0:
        # Straggler spike: this CS runs straggle_scale x long (DVFS /
        # migration made the core slow) — applied before preemption so
        # the stall is independent of the spiked duration.
        dur = dur + flt.straggle_extra(pm.seed, c_safe, gix, dur,
                                       pm.straggle_rate * eligible,
                                       pm.straggle_scale)
    if cfg.preempt_rate > 0.0:
        # Lock-holder preemption: the holder is descheduled mid-CS for
        # an Exp(preempt_scale) stall; every waiter eats it.
        dur = dur + flt.preempt_extra(pm.seed, c_safe, gix,
                                      pm.preempt_rate * eligible,
                                      pm.preempt_scale)
    if wakeup and cfg.wakeup_us > 0.0:
        dur = dur + pm.wakeup
    holder = st.holder.at[l].set(jnp.where(cond, c_safe, st.holder[l]))
    phase = st.phase.at[c_safe].set(
        jnp.where(cond, HOLDER, st.phase[c_safe]))
    t_ready = st.t_ready.at[c_safe].set(
        jnp.where(cond, t + dur, st.t_ready[c_safe]))
    return st._replace(holder=holder, phase=phase, t_ready=t_ready)


def park(st, cond, c, new_phase):
    """Send core c (if cond) into a passive phase (QUEUED/SPIN) — it
    carries t_ready=INF and is woken by a releaser's pick_next."""
    return st._replace(
        phase=st.phase.at[c].set(jnp.where(cond, new_phase, st.phase[c])),
        t_ready=st.t_ready.at[c].set(jnp.where(cond, INF, st.t_ready[c])))


def waiting_mask(st, cfg, tb, l, phase=QUEUED):
    """Cores parked in ``phase`` currently contending lock l — the
    scan-based waiter set used by queue-less policies (edf/shfl/ks_*)."""
    return jnp.logical_and(st.phase == phase, lock_vec(st, cfg, tb) == l)


def queueless_acquire(st, cfg, tb, pm, c, t, cond):
    """The queue-less acquire step (edf/shfl): grab when the lock is free
    and nobody waits, else park in QUEUED — the releaser's pick_next
    scans the waiting mask instead of popping a ring buffer."""
    l = lock_of(st, cfg, tb, c)
    free = st.holder[l] == -1
    no_wait = jnp.logical_not(jnp.any(waiting_mask(st, cfg, tb, l)))
    can_grab = jnp.logical_and(free, no_wait)
    grab = jnp.logical_and(can_grab, cond)
    wait = jnp.logical_and(jnp.logical_not(can_grab), cond)
    st = grant(st, cfg, tb, pm, grab, c, t)
    return park(st, wait, c, QUEUED)


# --------------------------------------------------------------------------
# The policy contract
# --------------------------------------------------------------------------

class LockPolicy:
    """Base class: one instance per registered policy (stateless — all
    per-run state lives in SimState / SimState.pol)."""

    #: registry key; also the ``SimConfig.policy`` value.
    name: str = None
    #: True iff the policy parks cores in STANDBY (gates the standby
    #: handler's existence in the compiled step).
    uses_standby: bool = False
    #: True iff the policy reads the per-epoch read/write uniform
    #: (``SimState.cur_rw``, CREW-style policies).  Statically gates
    #: whether the engine's key-sharded epoch draws include the
    #: STREAM_RW uniform at all (key-off runs never draw it).
    uses_rw: bool = False
    #: SimParams fields this policy reads (declarative; conformance-checked).
    param_slots: tuple = ()
    #: SimTables slots this policy reads: core fields by name, registered
    #: per-core columns as ``"col.<name>"`` (e.g. ``"col.slo_scale"``).
    table_slots: tuple = ()
    #: Names of registered ``SimTables.col`` columns this policy *owns*
    #: (it called ``repro.core.columns.register_column`` for them at
    #: import time) — conformance asserts they exist and sweep.
    own_columns: tuple = ()
    #: SimState fields / SimState.pol entries this policy owns.
    state_slots: tuple = ()
    #: sweep-axis name -> SimParams.pol slot (policy knobs as batch axes).
    sweep_axes: dict = {}
    #: host-side admission-scheduler analogue (repro.core.asl_schedule
    #: key) and fleet-dispatch analogue (repro.serving.dispatch policy
    #: name); None when the policy has no host counterpart.
    host_scheduler: str = None
    host_dispatch: str = None

    # -- state-slot declaration -------------------------------------------
    def init_params(self, cfg) -> dict:
        """Policy-owned traced knobs -> ``SimParams.pol`` (read
        ``policy_opts(cfg)`` for defaults; called with the REAL cfg)."""
        return {}

    def init_state(self, cfg, tb, pm) -> dict:
        """Policy-owned per-run state -> ``SimState.pol`` (called with
        the canonicalized cfg: read numeric knobs from ``pm``, not cfg)."""
        return {}

    # -- event hooks -------------------------------------------------------
    def on_acquire(self, st, cfg, tb, pm, c, t, cond):
        raise NotImplementedError

    def on_standby_expiry(self, st, cfg, tb, pm, c, t, cond):
        return st

    def on_release(self, st, cfg, tb, pm, c, t, ep_latency, last, cond):
        return st

    def pick_next(self, st, cfg, tb, pm, l, t, cond):
        raise NotImplementedError
