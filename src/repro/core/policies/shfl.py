"""ShflLock-style queue shuffling: big cores are shuffled ahead of
little waiters, bounded by a per-lock starvation counter — no AIMD, no
SLO feedback (the static throughput-first point the paper's Figure 5
proportional policy approximates).

The shuffle bound is a policy-owned knob: ``shfl_bound`` consecutive
head-bypasses force the true FIFO head through (so a little waiter is
bypassed at most ``shfl_bound`` grants — starvation-free by
construction).  It rides in ``SimParams.pol`` (traced, sweepable as the
``shfl_bound`` axis) and defaults from ``SimConfig.policy_kw``.

Queue-less like edf: FIFO order is the arrival order of the waiting
set (``attempt_t``; argmin index tie-break), big-forward shuffling is
the same scan restricted to big waiters.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.policies import register
from repro.core.policies.base import (INF, LockPolicy, grant, policy_opts,
                                      queueless_acquire, waiting_mask)

DEFAULT_BOUND = 4


@register
class ShflPolicy(LockPolicy):
    name = "shfl"
    table_slots = ("big",)
    state_slots = ("shfl_ctr",)
    param_slots = ("pol.shfl_bound",)
    sweep_axes = {"shfl_bound": "shfl_bound"}

    def init_params(self, cfg):
        return {"shfl_bound": jnp.int32(
            policy_opts(cfg).get("shfl_bound", DEFAULT_BOUND))}

    def init_state(self, cfg, tb, pm):
        return {"shfl_ctr": jnp.zeros(cfg.n_locks, jnp.int32)}

    def on_acquire(self, st, cfg, tb, pm, c, t, cond):
        return queueless_acquire(st, cfg, tb, pm, c, t, cond)

    def pick_next(self, st, cfg, tb, pm, l, t, cond):
        waiting = waiting_mask(st, cfg, tb, l)
        arr = jnp.where(waiting, st.attempt_t, INF)
        head = jnp.argmin(arr).astype(jnp.int32)
        big_wait = jnp.logical_and(waiting, tb.big == 1)
        big_head = jnp.argmin(
            jnp.where(big_wait, st.attempt_t, INF)).astype(jnp.int32)
        ctr = st.pol["shfl_ctr"][l]
        shuffle = jnp.logical_and(jnp.any(big_wait),
                                  ctr < pm.pol["shfl_bound"])
        pick = jnp.where(shuffle, big_head, head)
        # Count consecutive head-bypasses; granting the head (shuffled
        # or not) resets the bound.
        bypassed = jnp.logical_and(shuffle, pick != head)
        has = jnp.logical_and(jnp.any(waiting), cond)
        new_ctr = jnp.where(bypassed, ctr + 1, 0)
        st = st._replace(pol=dict(
            st.pol, shfl_ctr=st.pol["shfl_ctr"].at[l].set(
                jnp.where(has, new_ctr, ctr))))
        return grant(st, cfg, tb, pm, has, pick, t, wakeup=True)
