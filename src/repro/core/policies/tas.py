"""Test-and-set with an asymmetric success rate (paper Figure 3b/3c).

The winner among spinners at release is drawn with weight ``w_big`` for
big cores (w_big > 1 = big-core-affinity, < 1 = little-core-affinity).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policies import register
from repro.core.policies.base import (SPIN, LockPolicy, grant, lock_of,
                                      lock_vec, park, weighted_pick)


@register
class TasPolicy(LockPolicy):
    name = "tas"
    param_slots = ("w_big",)
    table_slots = ("big",)
    sweep_axes = {"w_big": "w_big"}     # built-in SimParams field
    host_scheduler = "greedy"
    host_dispatch = "fast-only"

    def on_acquire(self, st, cfg, tb, pm, c, t, cond):
        l = lock_of(st, cfg, tb, c)
        free = st.holder[l] == -1
        # Free -> grab; else spin (woken at release by weighted draw).
        grab = jnp.logical_and(free, cond)
        spin = jnp.logical_and(jnp.logical_not(free), cond)
        st = grant(st, cfg, tb, pm, grab, c, t)
        return park(st, spin, c, SPIN)

    def pick_next(self, st, cfg, tb, pm, l, t, cond):
        spinning = jnp.logical_and(st.phase == SPIN,
                                   lock_vec(st, cfg, tb) == l)
        key, sub = jax.random.split(st.key)
        w = jnp.where(tb.big == 1, pm.w_big, 1.0)
        winner, any_spin = weighted_pick(sub, jnp.where(spinning, w, 0.0))
        st = st._replace(key=jnp.where(cond, key, st.key))
        return grant(st, cfg, tb, pm, jnp.logical_and(any_spin, cond),
                     winner, t)
