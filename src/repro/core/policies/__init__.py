"""Pluggable lock-policy registry.

The simulator's event loop (:mod:`repro.core.simlock`) is policy-
agnostic: everything a policy decides — grab / queue / standby / spin on
an acquire attempt, who gets the lock at release, what feedback runs at
an epoch end — lives in a :class:`~repro.core.policies.base.LockPolicy`
subclass registered here.  ``simlock.POLICIES`` ids, the host-side
scheduler names (:mod:`repro.core.asl_schedule`) and the fleet-dispatch
policy names (:mod:`repro.serving.dispatch`) all derive from this one
registry, so a new policy lands everywhere at once (see
docs/simulator.md §Adding a lock policy).

Registration order is load-bearing: it fixes the integer policy ids
(``fifo=0, tas=1, prop=2, libasl=3, edf=4, shfl=5, dvfs_race=6``) —
append new policies, never reorder.
"""

from __future__ import annotations

from repro.core.policies.base import LockPolicy

#: name -> the singleton policy instance, in registration order.
REGISTRY: dict = {}


def register(cls):
    """Class decorator: instantiate and register a LockPolicy."""
    pol = cls()
    if not pol.name:
        raise ValueError(f"{cls.__name__} has no policy name")
    if pol.name in REGISTRY:
        raise ValueError(f"duplicate lock policy {pol.name!r}")
    REGISTRY[pol.name] = pol
    return cls


def get(name: str) -> LockPolicy:
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown lock policy {name!r}; registered: "
                         f"{sorted(REGISTRY)}") from None


def policy_ids() -> dict:
    """name -> stable integer id (registration order)."""
    return {name: i for i, name in enumerate(REGISTRY)}


def host_schedulers() -> dict:
    """Lock-policy name -> host admission-scheduler name (the
    asl_schedule analogue), for policies that have one."""
    return {p.name: p.host_scheduler for p in REGISTRY.values()
            if p.host_scheduler}


def dispatch_names() -> tuple:
    """Fleet-dispatch policy names (repro.serving.dispatch), in
    registry order."""
    return tuple(p.host_dispatch for p in REGISTRY.values()
                 if p.host_dispatch)


# Import order == registry order == policy ids.  The first four preserve
# the pre-registry POLICIES ids exactly.
from repro.core.policies import fifo as _fifo          # noqa: E402,F401
from repro.core.policies import tas as _tas            # noqa: E402,F401
from repro.core.policies import prop as _prop          # noqa: E402,F401
from repro.core.policies import libasl as _libasl      # noqa: E402,F401
from repro.core.policies import edf as _edf            # noqa: E402,F401
from repro.core.policies import shfl as _shfl          # noqa: E402,F401
from repro.core.policies import dvfs_race as _dvfs_race  # noqa: E402,F401
from repro.core.policies import keyshard as _keyshard  # noqa: E402,F401

__all__ = ["LockPolicy", "REGISTRY", "register", "get", "policy_ids",
           "host_schedulers", "dispatch_names"]
