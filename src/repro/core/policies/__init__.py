"""Pluggable lock-policy registry.

The simulator's event loop (:mod:`repro.core.simlock`) is policy-
agnostic: everything a policy decides — grab / queue / standby / spin on
an acquire attempt, who gets the lock at release, what feedback runs at
an epoch end — lives in a :class:`~repro.core.policies.base.LockPolicy`
subclass registered here.  ``simlock.POLICIES`` ids, the host-side
scheduler names (:mod:`repro.core.asl_schedule`) and the fleet-dispatch
policy names (:mod:`repro.serving.dispatch`) all derive from this one
registry, so a new policy lands everywhere at once (see
docs/simulator.md §Adding a lock policy).

Registration order is load-bearing: it fixes the integer policy ids
(``fifo=0, tas=1, prop=2, libasl=3, edf=4, shfl=5, dvfs_race=6``) —
append new policies, never reorder.
"""

from __future__ import annotations

from repro.core.policies.base import LockPolicy

#: name -> the singleton policy instance, in registration order.
REGISTRY: dict = {}


def register(cls):
    """Class decorator: instantiate and register a LockPolicy."""
    pol = cls()
    if not pol.name:
        raise ValueError(f"{cls.__name__} has no policy name")
    if pol.name in REGISTRY:
        raise ValueError(f"duplicate lock policy {pol.name!r}")
    REGISTRY[pol.name] = pol
    return cls


def get(name: str) -> LockPolicy:
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown lock policy {name!r}; registered: "
                         f"{sorted(REGISTRY)}") from None


def policy_ids() -> dict:
    """name -> stable integer id (registration order)."""
    return {name: i for i, name in enumerate(REGISTRY)}


class MergedPolicy(LockPolicy):
    """The union of several registered policies behind one LockPolicy —
    the multi-policy executable's dispatch layer.

    The member id rides *traced* in ``SimParams.pol_id``: every hook
    applies each member's hook under ``cond AND (pol_id == member id)``,
    so a whole policy x load grid compiles ONE executable and each sweep
    cell runs exactly its own policy.  This is sound because hooks are
    *fully conditional* (the switch-merge-safe contract of
    :class:`LockPolicy`): a masked-off member commits nothing — not even
    an RNG-key split — so each cell's trajectory is bit-identical to the
    single-policy executable's.

    Param/state slots union by name (the registry keeps pol-slot names
    globally unique — e.g. ``shfl_bound`` / ``race_ctr`` / ``jbsq_k``);
    a member only ever reads its own slots, so the union is inert for
    masked-off cells.  ``uses_standby`` / ``uses_rw`` are any-member ORs
    (the engine additionally masks the rw draws per cell, so a fifo cell
    in a set containing ks_crew still digests ``cur_rw == 1.0``).
    """

    def __init__(self, names):
        ids = policy_ids()
        self.names = tuple(names)
        self.members = tuple((ids[n], get(n)) for n in self.names)
        self.name = "+".join(self.names)
        self.uses_standby = any(m.uses_standby for _, m in self.members)
        self.uses_rw = any(m.uses_rw for _, m in self.members)
        self.param_slots = tuple(dict.fromkeys(
            s for _, m in self.members for s in m.param_slots))
        self.table_slots = tuple(dict.fromkeys(
            s for _, m in self.members for s in m.table_slots))
        self.state_slots = tuple(dict.fromkeys(
            s for _, m in self.members for s in m.state_slots))
        self.own_columns = tuple(dict.fromkeys(
            c for _, m in self.members for c in m.own_columns))
        self.sweep_axes = {}
        for _, m in self.members:
            for axis, slot in m.sweep_axes.items():
                if self.sweep_axes.setdefault(axis, slot) != slot:
                    raise ValueError(
                        f"policy set {self.names} maps sweep axis "
                        f"{axis!r} onto two different slots")

    def rw_member_ids(self) -> tuple:
        """Ids of members that read the per-epoch rw uniform — the
        engine's per-cell rw-draw mask (see simlock._rw_gate)."""
        return tuple(pid for pid, m in self.members if m.uses_rw)

    def init_params(self, cfg) -> dict:
        out = {}
        for _, m in self.members:
            out.update(m.init_params(cfg))
        return out

    def init_state(self, cfg, tb, pm) -> dict:
        out = {}
        for _, m in self.members:
            out.update(m.init_state(cfg, tb, pm))
        return out

    def _fan(self, hook, st, cond, pm, args):
        import jax.numpy as jnp
        for pid, m in self.members:
            st = getattr(m, hook)(
                st, *args, jnp.logical_and(cond, pm.pol_id == pid))
        return st

    def on_acquire(self, st, cfg, tb, pm, c, t, cond):
        return self._fan("on_acquire", st, cond, pm, (cfg, tb, pm, c, t))

    def on_standby_expiry(self, st, cfg, tb, pm, c, t, cond):
        import jax.numpy as jnp
        for pid, m in self.members:
            if m.uses_standby:
                st = m.on_standby_expiry(
                    st, cfg, tb, pm, c, t,
                    jnp.logical_and(cond, pm.pol_id == pid))
        return st

    def on_release(self, st, cfg, tb, pm, c, t, ep_latency, last, cond):
        return self._fan("on_release", st, cond, pm,
                         (cfg, tb, pm, c, t, ep_latency, last))

    def pick_next(self, st, cfg, tb, pm, l, t, cond):
        return self._fan("pick_next", st, cond, pm, (cfg, tb, pm, l, t))


_MERGED: dict = {}


def merged(names) -> MergedPolicy:
    """The cached :class:`MergedPolicy` for a policy-name tuple (one
    instance per distinct ``SimConfig.policy_set``)."""
    key = tuple(names)
    if key not in _MERGED:
        _MERGED[key] = MergedPolicy(key)
    return _MERGED[key]


def host_schedulers() -> dict:
    """Lock-policy name -> host admission-scheduler name (the
    asl_schedule analogue), for policies that have one."""
    return {p.name: p.host_scheduler for p in REGISTRY.values()
            if p.host_scheduler}


def dispatch_names() -> tuple:
    """Fleet-dispatch policy names (repro.serving.dispatch), in
    registry order."""
    return tuple(p.host_dispatch for p in REGISTRY.values()
                 if p.host_dispatch)


# Import order == registry order == policy ids.  The first four preserve
# the pre-registry POLICIES ids exactly.
from repro.core.policies import fifo as _fifo          # noqa: E402,F401
from repro.core.policies import tas as _tas            # noqa: E402,F401
from repro.core.policies import prop as _prop          # noqa: E402,F401
from repro.core.policies import libasl as _libasl      # noqa: E402,F401
from repro.core.policies import edf as _edf            # noqa: E402,F401
from repro.core.policies import shfl as _shfl          # noqa: E402,F401
from repro.core.policies import dvfs_race as _dvfs_race  # noqa: E402,F401
from repro.core.policies import keyshard as _keyshard  # noqa: E402,F401

__all__ = ["LockPolicy", "REGISTRY", "register", "get", "policy_ids",
           "host_schedulers", "dispatch_names"]
