"""EDF — earliest-deadline-first grant (latency-first baseline).

Every waiter's deadline is its epoch's SLO expiry (``epoch_start +
slo * slo_scale[core]`` — the same per-core class-SLO table LibASL
tracks); the releaser grants the most urgent waiter.  A pure
latency-first scheduler: it ignores core asymmetry entirely, so under
contention the slow cores' earlier deadlines drag the lock onto little
cores — the throughput anti-pode of ShflLock-style big-affinity, and
the baseline the paper's AIMD policy has to beat on *both* axes.

Queue-less: waiters park in QUEUED and the releaser scans the waiting
mask (INF-masked — padded cores can never win, so batched/padded/
sharded runs stay bit-identical).  Deadline arithmetic is exact i32
ticks: the per-core SLO is clamped to the starvation cap
(``max_window_us`` — also what makes a huge "pure-throughput" SLO
degrade to bounded arrival order instead of float-quantization index
bias), and exact deadline ties break by attempt time (arrival order),
not core index.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.policies import register
from repro.core.policies.base import (INF, LockPolicy, grant, queueless_acquire,
                                      ticks, waiting_mask)


@register
class EdfPolicy(LockPolicy):
    name = "edf"
    param_slots = ("slo",)
    table_slots = ("col.slo_scale",)

    def on_acquire(self, st, cfg, tb, pm, c, t, cond):
        return queueless_acquire(st, cfg, tb, pm, c, t, cond)

    def pick_next(self, st, cfg, tb, pm, l, t, cond):
        waiting = waiting_mask(st, cfg, tb, l)
        # i32 tick arithmetic stays exact where f32 ulp (8192 ticks at
        # slo=1e9us) would quantize every deadline into an index-order
        # scramble; the clamp keeps the sum far from i32 overflow AND
        # bounds how long any waiter can be deferred.
        slo_t = jnp.minimum(pm.slo * tb.col["slo_scale"],
                            jnp.float32(ticks(cfg.max_window_us))
                            ).astype(jnp.int32)
        dl = jnp.where(waiting, st.epoch_start + slo_t, INF)
        tie = jnp.logical_and(waiting, dl == jnp.min(dl))
        pick = jnp.argmin(jnp.where(tie, st.attempt_t,
                                    INF)).astype(jnp.int32)
        has = jnp.logical_and(jnp.any(waiting), cond)
        return grant(st, cfg, tb, pm, has, pick, t, wakeup=True)
