"""Strict FIFO handoff — the MCS-equivalent baseline (Implication 1)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.policies import register
from repro.core.policies.base import (LockPolicy, QUEUED, deq, enq, grant,
                                      lock_of, park, qlen)


@register
class FifoPolicy(LockPolicy):
    name = "fifo"
    state_slots = ("q", "q_head", "q_tail")
    host_scheduler = "fifo"
    host_dispatch = "fair"

    def on_acquire(self, st, cfg, tb, pm, c, t, cond):
        l = lock_of(st, cfg, tb, c)
        free = st.holder[l] == -1
        q_empty = qlen(st, l, 0) == 0
        grab = jnp.logical_and(jnp.logical_and(free, q_empty), cond)
        wait = jnp.logical_and(
            jnp.logical_not(jnp.logical_and(free, q_empty)), cond)
        st = grant(st, cfg, tb, pm, grab, c, t)
        st = enq(st, wait, l, 0, c)
        return park(st, wait, c, QUEUED)

    def pick_next(self, st, cfg, tb, pm, l, t, cond):
        nonempty = jnp.logical_and(qlen(st, l, 0) > 0, cond)
        st, cq = deq(st, nonempty, l, 0)
        return grant(st, cfg, tb, pm, nonempty, cq, t, wakeup=True)
