"""Static proportional policy (ShflLock-PB analogue, paper Figure 5):
1 little-core grant after every ``prop_n`` big-core grants."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.policies import register
from repro.core.policies.base import (LockPolicy, QUEUED, deq, enq, grant,
                                      lock_of, park, qlen)


@register
class PropPolicy(LockPolicy):
    name = "prop"
    param_slots = ("prop_n",)
    table_slots = ("big",)
    state_slots = ("prop_ctr", "q", "q_head", "q_tail")
    sweep_axes = {"prop_n": "prop_n"}   # built-in SimParams field

    def on_acquire(self, st, cfg, tb, pm, c, t, cond):
        l = lock_of(st, cfg, tb, c)
        is_big = tb.big[c] == 1
        free = st.holder[l] == -1
        q_empty = jnp.logical_and(qlen(st, l, 0) == 0, qlen(st, l, 1) == 0)
        grab = jnp.logical_and(jnp.logical_and(free, q_empty), cond)
        wait = jnp.logical_and(
            jnp.logical_not(jnp.logical_and(free, q_empty)), cond)
        st = grant(st, cfg, tb, pm, grab, c, t)
        b = jnp.where(is_big, 0, 1)
        st = enq(st, wait, l, b, c)
        return park(st, wait, c, QUEUED)

    def pick_next(self, st, cfg, tb, pm, l, t, cond):
        nb, nl = qlen(st, l, 0), qlen(st, l, 1)
        take_big = jnp.logical_and(jnp.logical_and(
            nb > 0, jnp.logical_or(st.prop_ctr[l] < pm.prop_n, nl == 0)),
            cond)
        take_little = jnp.logical_and(
            jnp.logical_and(jnp.logical_not(take_big), nl > 0), cond)
        st, cb = deq(st, take_big, l, 0)
        st, cl = deq(st, take_little, l, 1)
        nxt = jnp.where(take_big, cb, cl)
        has = jnp.logical_or(take_big, take_little)
        ctr = jnp.where(take_big, st.prop_ctr[l] + 1,
                        jnp.where(take_little, 0, st.prop_ctr[l]))
        st = st._replace(prop_ctr=st.prop_ctr.at[l].set(ctr))
        return grant(st, cfg, tb, pm, has, nxt, t, wakeup=True)
