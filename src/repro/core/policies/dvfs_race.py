"""dvfs_race — asymmetry- and DVFS-aware race-to-idle granting.

Race-to-idle (Saez 2024; Costero 2015 big.LITTLE schedulers): under
contention, hand the lock to the core that retires critical sections
fastest — big cores and high-DVFS cores — so the contention burst
finishes early and the slow cores can sit in their low-power wait
states instead of prolonging the busy period.  The alternative
("slow and steady") runs littles at low frequency and accepts the
longer busy period; which wins on energy-delay is exactly what the
``energy_efficiency`` figure measures across policies.

The grant priority is ``race_w * dvfs * (1 + big)``:

* ``dvfs`` — the energy layer's per-core frequency column
  (repro.core.energy): a core racing at 2x clock is twice as attractive.
* ``big`` — the static asymmetry bit doubles a big core's weight
  (its CS speedup is the paper's Sysbench gap).
* ``race_w`` — this policy's OWN registered column (declared here via
  :func:`repro.core.columns.register_column`): a per-core override to
  bias or ban cores from racing (e.g. thermally-throttled cores at 0).
  It exercises all three ownership mechanisms at once: an owned
  SimTables column (``race_w``), a traced ``SimParams.pol`` knob
  (``race_bound``, sweepable), and ``SimState.pol`` state
  (``race_ctr``).

Starvation is bounded exactly like shfl: after ``race_bound``
consecutive grants that bypassed the FIFO head, the head is forced
through — a slow waiter is deferred at most ``race_bound`` grants.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.columns import ColumnSpec, register_column
# Guarantees the ``dvfs`` column this policy reads is registered even
# when the simulator module has not been imported yet.
from repro.core import energy as _energy  # noqa: F401
from repro.core.policies import register
from repro.core.policies.base import (INF, LockPolicy, grant, policy_opts,
                                      queueless_acquire, waiting_mask)

register_column(ColumnSpec(
    name="race_w", dtype="f32", default=1.0, owner="dvfs_race",
    doc="per-core race-to-idle priority weight (0 bans a core from "
        "being shuffled forward; it still gets the forced-head grant)"))

DEFAULT_BOUND = 8


@register
class DvfsRacePolicy(LockPolicy):
    name = "dvfs_race"
    table_slots = ("big", "col.dvfs", "col.race_w")
    own_columns = ("race_w",)
    state_slots = ("race_ctr",)
    param_slots = ("pol.race_bound",)
    sweep_axes = {"race_bound": "race_bound"}

    def init_params(self, cfg):
        return {"race_bound": jnp.int32(
            policy_opts(cfg).get("race_bound", DEFAULT_BOUND))}

    def init_state(self, cfg, tb, pm):
        return {"race_ctr": jnp.zeros(cfg.n_locks, jnp.int32)}

    def on_acquire(self, st, cfg, tb, pm, c, t, cond):
        return queueless_acquire(st, cfg, tb, pm, c, t, cond)

    def pick_next(self, st, cfg, tb, pm, l, t, cond):
        waiting = waiting_mask(st, cfg, tb, l)
        speed = (tb.col["race_w"] * tb.col["dvfs"]
                 * (1.0 + tb.big.astype(jnp.float32)))
        # Masked score: non-waiters (and padded cores) at -1 can never
        # win, so batched/padded/sharded runs stay bit-identical.
        score = jnp.where(waiting, speed, -1.0)
        best = jnp.max(score)
        tie = jnp.logical_and(waiting, score == best)
        fast = jnp.argmin(jnp.where(tie, st.attempt_t,
                                    INF)).astype(jnp.int32)
        head = jnp.argmin(jnp.where(waiting, st.attempt_t,
                                    INF)).astype(jnp.int32)
        ctr = st.pol["race_ctr"][l]
        pick = jnp.where(ctr >= pm.pol["race_bound"], head, fast)
        bypassed = pick != head
        has = jnp.logical_and(jnp.any(waiting), cond)
        new_ctr = jnp.where(bypassed, ctr + 1, 0)
        st = st._replace(pol=dict(
            st.pol, race_ctr=st.pol["race_ctr"].at[l].set(
                jnp.where(has, new_ctr, ctr))))
        return grant(st, cfg, tb, pm, has, pick, t, wakeup=True)
