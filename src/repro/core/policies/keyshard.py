"""Key-aware dispatch policies for the key-sharded datastore axis.

Three plugins that read the per-epoch Zipf-drawn lock
(``repro.workloads.keys`` via ``SimState.cur_lock``) and exploit the
key->lock bucketing (bucket = key mod n_locks, rank-preserving — lock 0
is the hot bucket):

* ``ks_erew`` — EREW key affinity: every lock has a static *owner*
  core, active **big cores first** (the headline scenario: hot keys
  pinned to big cores).  The owner is shuffled ahead of the FIFO head,
  bounded by ``erew_bound`` consecutive bypasses (shfl-style
  starvation-free).
* ``ks_crew`` — CREW: the per-epoch STREAM_RW uniform classifies each
  epoch read (``cur_rw >= crew_wfrac``) or write; readers are served
  first (earliest-reader), writes are owner-exclusive (routed to the
  owner core when it is waiting-to-write), bounded by ``crew_bound``.
* ``ks_jbsq`` — bounded JBSQ(k): grant the *least-served* waiter
  (minimum epoch count, earliest-arrival tie-break) — the
  fairness-first anti-asymmetry contrast — forced back to the true
  FIFO head after ``jbsq_k`` consecutive head-bypasses.

CRCW has no plugin: plain ``fifo`` under a keyed config *is* the CRCW
baseline (any core may read or write any bucket, strict arrival
order); the keyshard figures label it ``crcw``.

All three are queue-less (edf/shfl-style waiting-mask scans) and
shape-independent: the owner map ranks inactive (padded) cores last,
so the owner of any lock is always an *active* core and padded runs
stay bit-identical to unpadded ones.  With the key gate off they
degrade to well-defined single-lock policies (owner = first big core,
every epoch a read), so the registry-wide conformance suite runs them
unchanged.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.policies import register
from repro.core.policies.base import (INF, LockPolicy, grant, policy_opts,
                                      queueless_acquire, waiting_mask)

DEFAULT_BOUND = 4       # erew/crew/jbsq head-bypass bound
DEFAULT_WFRAC = 0.5     # crew write fraction threshold


def _owner_of(tb, pm, l):
    """Static owner core of lock ``l``: active big cores claim the low
    (hot, because bucketing is rank-preserving) lock ids first, then
    active littles; inactive padded cores rank last so the owner is
    always active regardless of padding (shape-independence)."""
    n = tb.big.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    rank = jnp.where(idx < pm.n_active, 1 - tb.big, 2)
    pref = jnp.argsort(rank, stable=True).astype(jnp.int32)
    return pref[l % jnp.maximum(pm.n_active, 1)]


def _fifo_head(st, waiting):
    """Earliest attempt among the waiting set (argmin tie-break)."""
    return jnp.argmin(
        jnp.where(waiting, st.attempt_t, INF)).astype(jnp.int32)


def _bounded_grant(st, cfg, tb, pm, l, t, cond, waiting, prefer,
                   use_pref, ctr_slot, bound):
    """Grant ``prefer`` while the per-lock bypass counter is under
    ``bound``, else the true FIFO head; count consecutive bypasses
    (granting the head resets).  The shfl starvation bound, shared by
    all three keyshard policies."""
    head = _fifo_head(st, waiting)
    ctr = st.pol[ctr_slot][l]
    use = jnp.logical_and(use_pref, ctr < bound)
    pick = jnp.where(use, prefer, head)
    bypassed = jnp.logical_and(use, pick != head)
    has = jnp.logical_and(jnp.any(waiting), cond)
    new_ctr = jnp.where(bypassed, ctr + 1, 0)
    st = st._replace(pol=dict(st.pol, **{
        ctr_slot: st.pol[ctr_slot].at[l].set(
            jnp.where(has, new_ctr, ctr))}))
    return grant(st, cfg, tb, pm, has, pick, t, wakeup=True)


@register
class KsErewPolicy(LockPolicy):
    name = "ks_erew"
    table_slots = ("big",)
    param_slots = ("n_active", "pol.erew_bound")
    state_slots = ("erew_ctr",)
    sweep_axes = {"erew_bound": "erew_bound"}
    host_dispatch = "key-erew"

    def init_params(self, cfg):
        return {"erew_bound": jnp.int32(
            policy_opts(cfg).get("erew_bound", DEFAULT_BOUND))}

    def init_state(self, cfg, tb, pm):
        return {"erew_ctr": jnp.zeros(cfg.n_locks, jnp.int32)}

    def on_acquire(self, st, cfg, tb, pm, c, t, cond):
        return queueless_acquire(st, cfg, tb, pm, c, t, cond)

    def pick_next(self, st, cfg, tb, pm, l, t, cond):
        waiting = waiting_mask(st, cfg, tb, l)
        owner = _owner_of(tb, pm, l)
        return _bounded_grant(st, cfg, tb, pm, l, t, cond, waiting,
                              owner, waiting[owner], "erew_ctr",
                              pm.pol["erew_bound"])


@register
class KsCrewPolicy(LockPolicy):
    name = "ks_crew"
    uses_rw = True
    table_slots = ("big",)
    param_slots = ("n_active", "pol.crew_wfrac", "pol.crew_bound")
    state_slots = ("crew_ctr",)
    sweep_axes = {"crew_wfrac": "crew_wfrac", "crew_bound": "crew_bound"}
    host_dispatch = "key-crew"

    def init_params(self, cfg):
        kw = policy_opts(cfg)
        return {"crew_wfrac": jnp.float32(kw.get("crew_wfrac",
                                                 DEFAULT_WFRAC)),
                "crew_bound": jnp.int32(kw.get("crew_bound",
                                               DEFAULT_BOUND))}

    def init_state(self, cfg, tb, pm):
        return {"crew_ctr": jnp.zeros(cfg.n_locks, jnp.int32)}

    def on_acquire(self, st, cfg, tb, pm, c, t, cond):
        return queueless_acquire(st, cfg, tb, pm, c, t, cond)

    def pick_next(self, st, cfg, tb, pm, l, t, cond):
        waiting = waiting_mask(st, cfg, tb, l)
        # Epoch class: write when the STREAM_RW uniform falls under the
        # write fraction (cur_rw init/default is 1.0 = read, so the
        # key-off degenerate run is all-readers — plain earliest-first).
        writer = st.cur_rw < pm.pol["crew_wfrac"]
        readers = jnp.logical_and(waiting, jnp.logical_not(writer))
        r_head = _fifo_head(st, readers)
        owner = _owner_of(tb, pm, l)
        owner_writes = jnp.logical_and(waiting[owner], writer[owner])
        any_r = jnp.any(readers)
        # Readers first (earliest reader); else a write, owner-exclusive
        # when the owner wants it.  use_pref=False (no reader, owner
        # idle) falls through to the FIFO head — an ordinary writer.
        prefer = jnp.where(any_r, r_head,
                           jnp.where(owner_writes, owner, 0))
        use_pref = jnp.logical_or(any_r, owner_writes)
        return _bounded_grant(st, cfg, tb, pm, l, t, cond, waiting,
                              prefer, use_pref, "crew_ctr",
                              pm.pol["crew_bound"])


@register
class KsJbsqPolicy(LockPolicy):
    name = "ks_jbsq"
    param_slots = ("pol.jbsq_k",)
    state_slots = ("jbsq_ctr",)
    sweep_axes = {"jbsq_k": "jbsq_k"}
    host_dispatch = "key-jbsq"

    def init_params(self, cfg):
        return {"jbsq_k": jnp.int32(
            policy_opts(cfg).get("jbsq_k", DEFAULT_BOUND))}

    def init_state(self, cfg, tb, pm):
        return {"jbsq_ctr": jnp.zeros(cfg.n_locks, jnp.int32)}

    def on_acquire(self, st, cfg, tb, pm, c, t, cond):
        return queueless_acquire(st, cfg, tb, pm, c, t, cond)

    def pick_next(self, st, cfg, tb, pm, l, t, cond):
        waiting = waiting_mask(st, cfg, tb, l)
        # Least-served waiter: minimum completed-epoch count, earliest
        # arrival among the tied (two-stage argmin keeps i32 exact).
        served = jnp.where(waiting, st.ep_cnt, INF)
        tied = jnp.logical_and(waiting, st.ep_cnt == jnp.min(served))
        least = _fifo_head(st, tied)
        return _bounded_grant(st, cfg, tb, pm, l, t, cond, waiting,
                              least, jnp.any(waiting), "jbsq_ctr",
                              pm.pol["jbsq_k"])
