"""Reorderable lock — Algorithm 1 of the paper, verbatim semantics.

Exposes bounded reordering atop a replaceable FIFO lock:

* ``lock_immediately``  — enqueue now (``lock_fifo`` of the underlying lock).
* ``lock_reorder(window)`` — become a *standby* competitor: if the lock is
  observed free, enqueue at once; otherwise poll ``is_lock_free`` with binary
  exponential backoff until the reorder window expires, then enqueue.  Other
  competitors may enqueue (reorder) past a standby during its window — the
  window bounds the reordering.

An upper bound on the window (``MAX_WINDOW_NS``) keeps the lock
starvation-free.  The window is a hint, not a strict order constraint
(paper §3.2): a standby whose window expired still races FIFO-fairly from
``lock_fifo`` onward.

The blocking variant (paper footnote 3 / Bench-6) sleeps during the window
instead of spinning; select with ``blocking=True``.
"""

from __future__ import annotations

import time

from repro.core.locks import FIFOLock

MAX_WINDOW_NS = 100_000_000  # 100 ms (paper §4 maximum reorder window)


class ReorderableLock:
    """Algorithm 1. ``fifo`` must provide lock_fifo/unlock_fifo/is_lock_free."""

    def __init__(self, fifo=None, *, blocking: bool = False):
        self.fifo = fifo if fifo is not None else FIFOLock()
        self._blocking = blocking

    # -- Algorithm 1, line 1-3 -------------------------------------------
    def lock_immediately(self) -> None:
        self.fifo.lock_fifo()

    # -- Algorithm 1, line 5-17 ------------------------------------------
    def lock_reorder(self, window_ns: float) -> None:
        window_ns = min(window_ns, MAX_WINDOW_NS)
        if window_ns <= 0:
            # Window fully collapsed by AIMD: the standby phase is empty,
            # enqueue FIFO at once — no clock reads, no free-lock poll.
            self.fifo.lock_fifo()
            return
        if self.fifo.is_lock_free():  # line 7 fast path
            self.fifo.lock_fifo()
            return
        window_end = time.monotonic_ns() + window_ns
        cnt, next_check = 0, 1
        while time.monotonic_ns() < window_end:
            cnt += 1
            if cnt == next_check:  # line 10-13: exponential backoff checks
                if self.fifo.is_lock_free():
                    break
                next_check <<= 1
            if self._blocking:
                # Bench-6 variant: yield the core while standing by.
                time.sleep(min(1e-6 * next_check, 1e-3))
        self.fifo.lock_fifo()  # line 16

    # -- Algorithm 1, line 19-21 -------------------------------------------
    def unlock(self) -> None:
        self.fifo.unlock_fifo()

    def is_lock_free(self) -> bool:
        return self.fifo.is_lock_free()
