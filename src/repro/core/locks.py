"""Baseline lock implementations the paper compares against.

These are *real* locks used by the host-side runtime (serving engine,
checkpoint manager) and exercised by the correctness/property tests.  On this
1-core container their wall-clock scaling is meaningless — the performance
reproduction lives in :mod:`repro.core.simlock` (discrete-event AMP
simulator); here the contract is correctness: mutual exclusion, FIFO order
where promised, and the paper's structural behaviors (proportional batching,
TAS unfairness hook).

Primitives: CPython's ``threading.Lock.acquire(blocking=False)`` *is* a
test-and-set, which we use as the atomic; FIFO handoff uses per-waiter
``threading.Event`` (the queue-lock analogue of MCS — each waiter spins/waits
on its own node, the releaser wakes exactly its successor).
"""

from __future__ import annotations

import threading
import time
from collections import deque


class FIFOLock:
    """Queue lock with strict FIFO handoff (the MCS-equivalent).

    Each acquirer appends a node carrying a private Event; the releaser hands
    the lock to the head node only (local waiting, single wakeup — the MCS
    property that matters above the hardware level).
    """

    def __init__(self) -> None:
        self._mu = threading.Lock()  # emulates the atomic tail swap
        self._q: deque[threading.Event] = deque()
        self._held = False

    # -- paper interface -------------------------------------------------
    def lock_fifo(self) -> None:
        with self._mu:
            if not self._held and not self._q:
                self._held = True
                return
            ev = threading.Event()
            self._q.append(ev)
        ev.wait()  # FIFO handoff: predecessor sets exactly this event

    def unlock_fifo(self) -> None:
        with self._mu:
            if self._q:
                ev = self._q.popleft()
                ev.set()  # holder transfers ownership; _held stays True
            else:
                self._held = False

    def is_lock_free(self) -> bool:
        # Racy read by design (paper line 7/11: an opportunistic check).
        return not self._held

    # -- stdlib-ish aliases ----------------------------------------------
    lock = lock_fifo
    unlock = unlock_fifo
    acquire = lock_fifo
    release = unlock_fifo

    def __enter__(self):
        self.lock_fifo()
        return self

    def __exit__(self, *exc):
        self.unlock_fifo()


class TASLock:
    """Test-and-set spinlock with optional exponential backoff.

    The winner among concurrent spinners is whoever's TAS lands first —
    unfair by construction (the paper's latency-collapse baseline).
    """

    def __init__(self, backoff: bool = True, max_backoff_s: float = 1e-3):
        self._flag = threading.Lock()
        self._backoff = backoff
        self._max_backoff_s = max_backoff_s

    def lock(self) -> None:
        delay = 1e-6
        while not self._flag.acquire(blocking=False):  # the TAS
            if self._backoff:
                time.sleep(delay)
                delay = min(delay * 2, self._max_backoff_s)
            else:
                time.sleep(0)  # yield; pure spin would livelock under GIL

    def unlock(self) -> None:
        self._flag.release()

    def is_lock_free(self) -> bool:
        return not self._flag.locked()

    acquire = lock
    release = unlock

    def __enter__(self):
        self.lock()
        return self

    def __exit__(self, *exc):
        self.unlock()


class TicketLock:
    """FIFO via fetch-and-increment tickets (paper's `ticket` baseline)."""

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._next = 0
        self._serving = 0
        self._cv = threading.Condition(self._mu)

    def lock(self) -> None:
        with self._cv:
            my = self._next
            self._next += 1
            while self._serving != my:
                self._cv.wait()

    def unlock(self) -> None:
        with self._cv:
            self._serving += 1
            self._cv.notify_all()

    def is_lock_free(self) -> bool:
        return self._serving == self._next

    acquire = lock
    release = unlock

    def __enter__(self):
        self.lock()
        return self

    def __exit__(self, *exc):
        self.unlock()


class ProportionalLock:
    """The static proportional policy (SHFL-PB10 analogue, paper §4).

    Two FIFO queues (big/little); after every ``proportion`` big-core grants
    one little-core grant is allowed — the static trade-off the paper shows
    cannot meet a latency target (Figure 5).  ``is_big`` classifies the
    calling thread (injected; on real AMP it is a core-id table lookup).
    """

    def __init__(self, is_big, proportion: int = 10):
        self._mu = threading.Lock()
        self._big: deque[threading.Event] = deque()
        self._little: deque[threading.Event] = deque()
        self._held = False
        self._ctr = 0
        self._is_big = is_big
        self._n = proportion

    def lock(self) -> None:
        with self._mu:
            if not self._held and not self._big and not self._little:
                self._held = True
                return
            ev = threading.Event()
            (self._big if self._is_big() else self._little).append(ev)
        ev.wait()

    def unlock(self) -> None:
        with self._mu:
            nxt = None
            if self._big and (self._ctr < self._n or not self._little):
                nxt = self._big.popleft()
                self._ctr += 1
            elif self._little:
                nxt = self._little.popleft()
                self._ctr = 0
            if nxt is not None:
                nxt.set()
            else:
                self._held = False

    def is_lock_free(self) -> bool:
        return not self._held

    acquire = lock
    release = unlock

    def __enter__(self):
        self.lock()
        return self

    def __exit__(self, *exc):
        self.unlock()
