"""LibASL — Algorithms 2 & 3: epoch annotation API + the asymmetry-aware mutex.

Usage (paper Figure 6)::

    asl = LibASL(is_big_core=lambda: my_role_is_big())
    m = asl.mutex()
    while serving:
        asl.epoch_start(5)
        with m:
            ...critical section...
        asl.epoch_end(5, slo_ns=1000)

* ``epoch_start/epoch_end`` keep **per-thread, per-epoch-id** AIMD window
  state (24 bytes in the paper; a small dataclass here) and support nesting
  via a per-thread stack; the innermost epoch's window governs
  (paper §3.4: nested epochs prioritize the inner one).
* ``mutex()`` returns a drop-in lock: big-core callers take
  ``lock_immediately``; little-core callers take ``lock_reorder`` with the
  current epoch's window (``MAX_WINDOW_NS`` outside any epoch, so
  non-latency-critical apps transparently get maximal-throughput ordering
  without starvation).

The paper redirects ``pthread_mutex_lock`` by weak-symbol interposition;
the Python analogue is this object being a context manager compatible with
``threading.Lock`` call sites.
"""

from __future__ import annotations

import threading
import time

from repro.core.aimd import AIMDWindow, unit_for
from repro.core.reorderable import MAX_WINDOW_NS, ReorderableLock

DEFAULT_WINDOW_NS = 1_000.0


class _EpochTLS(threading.local):
    def __init__(self):
        self.epochs: dict[int, AIMDWindow] = {}
        # Per-epoch-id stack of start timestamps: reentrant same-id
        # nesting pops LIFO, so an inner end measures the inner start.
        self.starts: dict[int, list[int]] = {}
        self.cur_epoch_id: int = -1
        self.stack: list[int] = []


class LibASL:
    """Process-wide LibASL runtime: epoch registry + mutex factory."""

    def __init__(self, is_big_core, *, pct: float = 99.0,
                 clock_ns=time.monotonic_ns, fifo_factory=None,
                 blocking: bool = False):
        self.is_big_core = is_big_core
        self.pct = pct
        self._clock = clock_ns
        self._fifo_factory = fifo_factory
        self._blocking = blocking
        self._tls = _EpochTLS()

    # -- Algorithm 2 -------------------------------------------------------
    def epoch_start(self, epoch_id: int) -> None:
        tls = self._tls
        if tls.cur_epoch_id >= 0:
            tls.stack.append(tls.cur_epoch_id)  # nested epoch support
        tls.cur_epoch_id = epoch_id
        if epoch_id not in tls.epochs:
            tls.epochs[epoch_id] = AIMDWindow(
                window=DEFAULT_WINDOW_NS,
                unit=unit_for(DEFAULT_WINDOW_NS, self.pct), pct=self.pct,
                max_window=MAX_WINDOW_NS)
        tls.starts.setdefault(epoch_id, []).append(self._clock())

    def epoch_end(self, epoch_id: int, slo_ns: float) -> float:
        """Returns the measured epoch latency (ns).

        Raises ``RuntimeError`` for an ``epoch_end`` with no matching
        ``epoch_start`` — silently measuring a ~0 latency here would feed
        a bogus never-violated sample into AIMD and inflate the caller's
        reorder window.  Ending an *outer* epoch while an inner one is
        still open removes it from the nesting stack without disturbing
        the innermost (governing) epoch.
        """
        tls = self._tls
        opens = tls.starts.get(epoch_id)
        if not opens:
            raise RuntimeError(
                f"epoch_end({epoch_id}) without a matching epoch_start")
        latency = self._clock() - opens.pop()
        if not opens:
            del tls.starts[epoch_id]
        if not self.is_big_core():  # paper line 21: big cores skip adjustment
            tls.epochs[epoch_id].update(latency, slo_ns)
        if tls.cur_epoch_id == epoch_id:
            tls.cur_epoch_id = tls.stack.pop() if tls.stack else -1
        elif epoch_id in tls.stack:  # mismatched nesting: drop the outer
            # (innermost occurrence, so reentrant same-id nesting unwinds
            # in order); the governing inner epoch stays current.
            del tls.stack[len(tls.stack) - 1
                          - tls.stack[::-1].index(epoch_id)]
        return latency

    def current_window_ns(self) -> float:
        tls = self._tls
        if tls.cur_epoch_id < 0:
            return MAX_WINDOW_NS  # line 5 of Algorithm 3: default max window
        return tls.epochs[tls.cur_epoch_id].window

    # -- Algorithm 3 -------------------------------------------------------
    def mutex(self) -> "ASLMutex":
        fifo = self._fifo_factory() if self._fifo_factory else None
        return ASLMutex(self, ReorderableLock(fifo, blocking=self._blocking))


class ASLMutex:
    """Drop-in mutex: dispatches per core type (paper Algorithm 3)."""

    def __init__(self, runtime: LibASL, reorderable: ReorderableLock):
        self._rt = runtime
        self._lock = reorderable

    def lock(self) -> None:
        if self._rt.is_big_core():
            self._lock.lock_immediately()
        else:
            self._lock.lock_reorder(self._rt.current_window_ns())

    def unlock(self) -> None:
        self._lock.unlock()

    acquire = lock
    release = unlock

    def __enter__(self):
        self.lock()
        return self

    def __exit__(self, *exc):
        self.unlock()
