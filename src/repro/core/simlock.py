"""Discrete-event AMP lock simulator — the paper's experiments as a JAX module.

This container has one CPU core, so the paper's wall-clock experiments on
asymmetric silicon cannot be re-run directly.  Instead we reproduce them on a
deterministic discrete-event simulation of an AMP: ``N`` cores with per-core
speed factors run (non-critical section → acquire → critical section →
release) loops against ``L`` shared locks under a pluggable lock policy.

Policies are plugins (:mod:`repro.core.policies`): the event loop here is
policy-agnostic — it looks the policy up in the registry and dispatches
the ``on_acquire`` / ``on_standby_expiry`` / ``on_release`` / ``pick_next``
hooks.  Registered out of the box: the paper's baselines ``fifo`` (MCS),
``tas`` (asymmetric test-and-set), ``prop`` (ShflLock-PB analogue) and
``libasl`` (the paper's AIMD reorder window), plus ``edf``
(earliest-deadline grant off the per-core SLO table) and ``shfl``
(ShflLock-style bounded big-forward shuffling).  ``POLICIES`` ids derive
from the registry; docs/simulator.md §Adding a lock policy has the
plugin contract.

Event model (one pending event per core; the phase of the core at the
head of the event clock selects the handler from the dispatch table):
  NONCRIT end  → acquire attempt (policy hook)
  STANDBY end  → reorder window expired (policy hook; only compiled in
                 for policies that declare ``uses_standby``)
  HOLDER end   → release: record latencies, advance epoch, pick next holder
  ARRIVAL due  → open-loop mode (``wl_open``): the next request arrives —
                 start the epoch at its true arrival time, draw the
                 following arrival from the workload's arrival process
QUEUED / SPIN cores carry t_ready=INF and are woken by the releaser.

Batched sweep engine (docs/simulator.md):

The simulator is *one compiled executable per (policy, shape)*, not per
parameter point.  Everything numeric that the paper sweeps — SLO, ``w_big``,
``prop_n``, seed, initial reorder windows, active core count, segment
durations, the long-epoch mix and the wakeup cost — is carried in two traced
pytrees (:class:`SimTables` from the static program, :class:`SimParams` per
run) threaded through the event handlers, while :class:`SimConfig` is
*canonicalized* before being used as the jit static argument.  Thread-count
scaling runs padded to ``cfg.n_cores`` with an active-core mask, so fig1's
n=1..8 share one executable.  ``sweep(cfg, axes)`` runs one whole figure
as a single ``lax.map``-batched call; the inner loop retires ``cfg.chunk``
events per ``lax.scan`` chunk inside the outer ``while_loop`` to amortize
dispatch.

Stochastic workloads (``wl=True``; repro.workloads, docs/workloads.md)
scale each epoch's think and service segments by counter-based draws —
offered load (``arrival_rate``), service shape (``cv``/``mix``) and
burstiness sweep as traced axes too, and the per-core ``slo_scale``
table models multi-class tenants side by side.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aimd, policies
from repro.core.policies.base import (ARRIVAL, HOLDER, INF, NONCRIT, QUEUED,
                                      SPIN, STANDBY, US)
# Queue/grant helpers live next to the policy contract now; the old
# underscored names stay importable here (tests / downstream callers).
from repro.core.policies.base import deq as _deq
from repro.core.policies.base import enq as _enq
from repro.core.policies.base import grant as _grant
from repro.core.policies.base import qlen as _qlen
from repro.core.policies.base import ticks as _ticks
from repro.core.policies.base import weighted_pick as _weighted_pick
from repro.core import columns as colreg
from repro.core import energy as _energy  # registers the DVFS/power columns
from repro.dist.hlo_analysis import executable_stats
from repro.core.policies.base import lock_of as _lock_of
from repro.core import stats
from repro.faults import model as flt
from repro.workloads import generators as wlg
from repro.workloads import keys as wlk

# name -> stable integer id, derived from the policy registry
# (registration order; the first four match the pre-registry constants).
POLICIES = policies.policy_ids()


def _validate_config(cfg) -> None:
    """Reject NaN / negative / out-of-range fields and unknown policy
    names at construction (``SimConfig.__post_init__``) — a bad knob
    must raise here, not produce a silent garbage sweep.  Every bound
    admits the `_canon` replacement values (canonicalized configs pass
    through this too)."""
    if cfg.policy not in POLICIES:
        import difflib
        hint = difflib.get_close_matches(cfg.policy, POLICIES, n=1)
        raise ValueError(
            f"unknown lock policy {cfg.policy!r}; registered: "
            f"{sorted(POLICIES)}"
            + (f" -- did you mean {hint[0]!r}?" if hint else ""))
    if cfg.policy_set:
        for p in cfg.policy_set:
            if p not in POLICIES:
                raise ValueError(
                    f"policy_set entry {p!r} is not registered; "
                    f"registered: {sorted(POLICIES)}")
        if len(set(cfg.policy_set)) != len(cfg.policy_set):
            raise ValueError(
                f"policy_set has duplicates: {cfg.policy_set!r}")
        if cfg.policy not in cfg.policy_set:
            raise ValueError(
                f"policy {cfg.policy!r} is not in "
                f"policy_set {cfg.policy_set!r}")

    def chk(name, lo=None, hi=None, lo_open=False):
        v = getattr(cfg, name)
        if v != v:  # NaN (ints compare equal to themselves)
            raise ValueError(f"SimConfig.{name} is NaN")
        if lo is not None and (v < lo or (lo_open and v == lo)):
            raise ValueError(f"SimConfig.{name} must be "
                             f"{'>' if lo_open else '>='} {lo}, got {v!r}")
        if hi is not None and v > hi:
            raise ValueError(f"SimConfig.{name} must be <= {hi}, got {v!r}")

    for name in ("long_epoch_prob", "wl_mix", "wl_amp",
                 "preempt_rate", "churn_rate", "straggle_rate"):
        chk(name, 0.0, 1.0)
    for name in ("inter_epoch_us", "wakeup_us", "default_window_us",
                 "max_window_us", "w_big", "wl_cv", "wl_period_us",
                 "preempt_scale_us", "long_epoch_scale"):
        chk(name, 0.0)
    for name in ("sim_time_us", "wl_rate", "wl_burst", "wl_mix_scale",
                 "churn_period_us"):
        chk(name, 0.0, lo_open=True)
    chk("wl_burst_len", 0.0)
    chk("straggle_scale", 1.0)
    chk("pct", 0.0, 100.0, lo_open=True)
    for name in ("n_cores", "n_locks", "epcap", "max_events", "chunk",
                 "prop_n"):
        chk(name, 1)
    chk("n_keys", 0)
    chk("hist_buckets", 4)
    chk("hist_lo_us", 0.0, lo_open=True)
    chk("hist_warmup", 0)
    if not cfg.hist_hi_us > cfg.hist_lo_us:
        raise ValueError(
            f"SimConfig.hist_hi_us must be > hist_lo_us, got "
            f"hi={cfg.hist_hi_us!r} lo={cfg.hist_lo_us!r}")
    import math
    if not math.isfinite(cfg.zipf_theta) or cfg.zipf_theta < 0.0:
        raise ValueError("SimConfig.zipf_theta must be finite and >= 0, "
                         f"got {cfg.zipf_theta!r}")
    if 0 < cfg.n_keys < cfg.n_locks:
        raise ValueError(
            f"SimConfig.n_keys={cfg.n_keys} is smaller than "
            f"n_locks={cfg.n_locks}: every lock needs at least one key "
            f"(raise n_keys or lower n_locks)")
    if len(cfg.seg_cs_us) != len(cfg.seg_noncrit_us) or \
            len(cfg.seg_cs_us) != len(cfg.seg_lock):
        raise ValueError("seg_noncrit_us / seg_cs_us / seg_lock must have "
                         "equal lengths")
    if not cfg.seg_cs_us:
        raise ValueError("epoch program needs at least one segment")
    for name in ("seg_noncrit_us", "seg_cs_us", "big", "speed_cs",
                 "speed_nc"):
        vals = getattr(cfg, name)
        if any(v != v or v < 0 for v in vals):
            raise ValueError(f"SimConfig.{name} has a NaN/negative entry: "
                             f"{vals!r}")
    # Registered per-core columns (repro.core.columns): numeric specs
    # reject NaN/negative entries; ``positive`` specs (dvfs divides the
    # segment durations) additionally reject zero.
    for name, _ in cfg.columns:
        spec = colreg.lookup(name)      # did-you-mean on unknown names
        if spec.field:
            raise ValueError(
                f"column {name!r} has a dedicated SimConfig field "
                f"{spec.field!r}; set that (or use with_columns)")
    for spec in colreg.COLUMNS.values():
        if not spec.numeric:
            continue
        vals = spec.raw_values(cfg)
        if any(v != v or v < 0 for v in vals):
            raise ValueError(f"SimConfig.{spec.axis} has a NaN/negative "
                             f"entry: {vals!r}")
        if spec.positive and any(v == 0 for v in vals):
            raise ValueError(f"SimConfig.{spec.axis} entries must be "
                             f"> 0, got {vals!r}")
    for name in ("big", "speed_cs", "speed_nc"):
        if len(getattr(cfg, name)) < cfg.n_cores:
            raise ValueError(f"SimConfig.{name} has "
                             f"{len(getattr(cfg, name))} entries for "
                             f"{cfg.n_cores} cores")
    if any(not 0 <= l < cfg.n_locks for l in cfg.seg_lock):
        raise ValueError(f"seg_lock ids must be in [0, {cfg.n_locks}), "
                         f"got {cfg.seg_lock!r}")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Static simulator configuration (hashable -> usable as jit static arg).

    ``n_cores`` is the *padded* core count of the compiled executable; runs
    may activate fewer cores (``n_cores`` sweep axis / ``n_active`` param).
    Numeric fields that are sweepable (w_big, prop_n, default_window_us,
    long_epoch_*, wakeup_us, segment durations) are defaults only — they are
    canonicalized out of the jit key and traced, so two configs differing
    only in those share one executable.
    """

    policy: str = "fifo"
    # Merged multi-policy executable (docs/simulator.md §Fused step
    # kernel & multi-policy executables): a non-empty tuple of
    # registered policy names compiles ONE executable dispatching all
    # of them on the *traced* ``SimParams.pol_id`` — a policy x load
    # sweep grid then costs ~1 compilation instead of n_policies.
    # ``policy`` must be a member (it picks this run's id); results are
    # bit-identical to the single-policy executable (hooks are fully
    # conditional, so masked-off members commit nothing).  Usually set
    # via a ``"policy"`` sweep axis rather than by hand.
    policy_set: tuple = ()
    # Route the per-event fused step through the Pallas kernel
    # (repro.kernels.simstep) instead of the plain jnp/XLA lowering.
    # Bit-identical results either way (the kernel runs the same traced
    # step); _canon keeps the bit in the jit key (different lowering ->
    # different executable) but nothing else about sweep semantics
    # changes.  CPU builds run the kernel in interpret mode.
    use_pallas: bool = False
    n_cores: int = 8
    big: tuple = (1, 1, 1, 1, 0, 0, 0, 0)          # 4 big + 4 little (M1)
    speed_cs: tuple = (1.0,) * 4 + (3.75,) * 4     # CS slowdown (Sysbench gap)
    speed_nc: tuple = (1.0,) * 4 + (1.8,) * 4      # non-CS slowdown (NOP gap)
    # Epoch program: S segments of (noncrit_us, cs_us, lock_id)
    seg_noncrit_us: tuple = (1.0,)
    seg_cs_us: tuple = (3.0,)
    seg_lock: tuple = (0,)
    inter_epoch_us: float = 5.0
    n_locks: int = 1
    # Key-sharded datastore mode (repro.workloads.keys, docs/
    # workloads.md §Key-sharded traffic): ``n_keys > 0`` switches every
    # epoch's lock from the static per-segment program (``seg_lock``) to
    # a per-(core, epoch) Zipf(``zipf_theta``)-drawn key bucketed over
    # the first ``n_locks`` locks (key % n_locks — rank-preserving, so
    # lock 0 is the hot bucket).  Only the on/off bit is jit-static; the
    # key count, exponent and active lock count ride traced in
    # SimParams, so ``n_keys`` / ``zipf_theta`` / ``n_locks`` sweep as
    # batch axes (``n_locks`` stays the padded state shape).
    n_keys: int = 0
    zipf_theta: float = 0.99      # YCSB-default skew; 0 = uniform
    pct: float = 99.0
    w_big: float = 1.0            # TAS affinity weight
    prop_n: int = 10              # proportional policy ratio
    default_window_us: float = 10.0
    max_window_us: float = 100_000.0   # 100 ms upper bound (starvation-free)
    sim_time_us: float = 100_000.0
    epcap: int = 8192             # latency ring size
    # Constant-memory streaming tail metrics (docs/simulator.md
    # §Streaming metrics).  ``hist`` is the single jit-static on/off bit:
    # when set, every epoch/CS latency sample is also scatter-added into
    # fixed-size log-bucketed ``u32[N, hist_buckets]`` histograms
    # (``SimState.ep_hist`` / ``cs_hist``), so tail percentiles stay
    # bounded-error at ANY run length — the ``f32[N, epcap]`` rings
    # silently overwrite history once a core retires > epcap samples.
    # ``hist_buckets`` is shape-static (like ``epcap``); the bucket
    # range [hist_lo_us, hist_hi_us) and the warmup cutoff ride traced
    # (SimTables / SimParams), so gate-off runs are bit-identical to
    # pre-histogram builds and bucket-range variants share executables.
    hist: bool = False
    hist_buckets: int = 512
    hist_lo_us: float = 0.1
    hist_hi_us: float = 1e6
    hist_warmup: int = 32         # samples/core skipped (match summarize)
    max_events: int = 5_000_000
    # Bench-3: heterogeneous epochs — with prob p the next epoch's
    # non-critical work is scale x longer (long request mixed with short).
    long_epoch_prob: float = 0.0
    long_epoch_scale: float = 100.0
    # Bench-6: blocking locks — FIFO handoff to a parked waiter pays a
    # wakeup latency; a standby grabbing a free lock (spinning) does not.
    wakeup_us: float = 0.0
    # Fault injection (repro.faults, docs/faults.md): lock-holder
    # preemption (the holder is descheduled mid-CS for an Exp(scale)
    # stall), core churn (during an "off" slot a core's acquire attempts
    # bounce to the next slot boundary — leave/rejoin on a schedule) and
    # straggler CS spikes (a critical section runs scale x long).  Only
    # the on/off bit of each rate is jit-static; the values ride traced
    # in SimParams, so preempt_rate / preempt_scale / churn_rate /
    # straggle_rate / straggle_scale sweep as batch axes.
    preempt_rate: float = 0.0
    preempt_scale_us: float = 50.0
    churn_rate: float = 0.0
    churn_period_us: float = 500.0
    straggle_rate: float = 0.0
    straggle_scale: float = 10.0
    # Per-core fault eligibility (1 = faults may hit this core; () ->
    # all eligible).  Rides traced in SimTables as a multiplier on the
    # fault rates, so it is a sweepable table axis and an all-zero mask
    # is bit-identical to a fault-free run.
    fault_mask: tuple = ()
    # DVFS + power model (repro.core.energy, docs/energy.md).  ``dvfs``
    # is the per-core frequency multiplier (() -> all 1.0): it divides
    # the host-built segment durations (a faster clock shortens work)
    # and rides traced so power can scale with f^3.  The four power
    # tables are per-core watts by phase (active CS / busy-wait spin /
    # parked in queue / idle); any non-empty table flips the single
    # static energy gate on (``_energy_on``) — the in-sim energy
    # integration only exists in the HLO when some power is modeled.
    # All five ride as registered SimTables columns (sweepable).
    dvfs: tuple = ()
    p_cs: tuple = ()
    p_spin: tuple = ()
    p_park: tuple = ()
    p_idle: tuple = ()
    # Stochastic workload model (repro.workloads.generators): per-epoch
    # think (arrival) and service-time scaling.  ``wl`` is the single
    # on/off jit-static bit (it gates whether the draws exist in the HLO
    # at all); every other field below is traced via SimParams, so
    # arrival_rate / cv / mix / burstiness sweep as batch axes.
    wl: bool = False
    wl_process: str = "poisson"   # ARRIVALS: closed|poisson|mmpp|diurnal
    wl_service: str = "det"       # SERVICES: det|exp|lognormal|bimodal
    # Open-loop mode (second static workload bit): arrivals are *events*
    # — each core runs an open queue whose requests arrive at absolute
    # times drawn from ``wl_process`` (mean gap = wl_rate x the closed-
    # loop think budget inter+noncrit), independent of completions, and
    # epoch latency is the full sojourn from arrival (queueing included).
    # Implies ``wl``; think-scaling is replaced by the pending-ARRIVAL
    # event (docs/workloads.md §Open-loop simlock).
    wl_open: bool = False
    # Per-core service-distribution override (multi-class tenants): a
    # SERVICES name per core, or None/"" to inherit ``wl_service``.
    # Rides as the traced ``wl_service`` id column of SimTables, so
    # mixed-shape cells share one executable (sweepable table axis).
    wl_service_per_core: tuple = ()
    wl_rate: float = 1.0          # offered load: mean think x= 1/rate
    wl_cv: float = 1.0            # lognormal service cv
    wl_mix: float = 0.0           # bimodal Get/Put long-mode probability
    wl_mix_scale: float = 10.0    # bimodal long/short ratio
    wl_burst: float = 1.0         # MMPP on/off rate ratio (1 = plain)
    wl_burst_len: float = 8.0     # mean epochs per MMPP phase
    wl_amp: float = 0.0           # diurnal ramp amplitude in [0,1)
    wl_period_us: float = 0.0     # diurnal period (0 -> sim_time_us)
    # Per-core SLO scale (multi-class tenancy; () -> all ones).  Rides
    # traced in SimTables, so mixed-tenant cells share one executable.
    slo_scale: tuple = ()
    # Policy-owned numeric knobs, as a hashable (name, value) tuple —
    # read by the registered policy's ``init_params`` into the traced
    # ``SimParams.pol`` dict (canonicalized out of the jit key), e.g.
    # ``policy_kw=(("shfl_bound", 8),)`` for the shfl policy.
    policy_kw: tuple = ()
    # Values for registered per-core columns that have NO dedicated
    # SimConfig field (plugin-owned columns, e.g. dvfs_race's
    # ``race_w``), as a hashable ((name, per-core tuple), ...) tuple.
    # Prefer ``with_columns(cfg, name=values)``, which routes dedicated-
    # field columns to their field and validates names (did-you-mean).
    columns: tuple = ()
    # Events retired per lax.scan chunk inside the outer while_loop
    # (amortizes the loop-condition check; results are chunk-invariant —
    # the live-guard in _step retires partial tails as no-ops).  128
    # measured best on CPU for both the single and the batched path.
    chunk: int = 128

    def __post_init__(self):
        _validate_config(self)

    @property
    def policy_id(self) -> int:
        return POLICIES[self.policy]


class SimTables(NamedTuple):
    """Per-program arrays, precomputed once and threaded through handlers
    (traced, so segment-duration sweeps share one executable)."""

    big: jnp.ndarray       # i32[N] 1 = big core
    cs_dur: jnp.ndarray    # i32[N,S] CS ticks per (core, segment)
    nc_dur: jnp.ndarray    # i32[N,S] non-CS ticks per (core, segment)
    inter: jnp.ndarray     # i32[N] inter-epoch ticks per core
    seg_lock: jnp.ndarray  # i32[S] lock id per segment
    # Streaming-histogram bucket layout (repro.core.stats.layout), the
    # host-precomputed log-spaced edge parameterization: log2 of the
    # lowest finite edge (ticks) and 1/log2 of the bucket growth factor.
    # Traced scalars — dead code unless ``cfg.hist`` (the static gate).
    hist_log2_lo: jnp.ndarray   # f32 log2(hist_lo_us * US)
    hist_inv_log2g: jnp.ndarray  # f32 1 / log2(g)
    # Registered per-core columns (repro.core.columns): every declared
    # ColumnSpec — the tenancy/fault/energy built-ins (slo_scale,
    # wl_service, ft_mask, dvfs, p_*) plus policy-owned ones — as
    # name -> [N] arrays.  Each is a sweepable table axis.
    col: dict


class SimParams(NamedTuple):
    """Per-run traced scalars — the sweepable batch axes."""

    slo: jnp.ndarray         # f32 ticks
    # Registry id of the policy THIS run dispatches (POLICIES[policy]).
    # Traced, so a merged multi-policy executable (cfg.policy_set)
    # selects each cell's member without recompiling; ignored by
    # single-policy executables (whose hooks never read it).
    pol_id: jnp.ndarray      # i32
    w_big: jnp.ndarray       # f32 TAS affinity weight
    prop_n: jnp.ndarray      # i32 proportional ratio
    n_active: jnp.ndarray    # i32 cores actually running (<= N padded)
    seed: jnp.ndarray        # i32 PRNG seed
    # Sim horizon in ticks.  Traced (a ``sim_time_us`` sweep axis), so
    # lanes of one batched executable may run *different* durations —
    # the step-utilization lever: a vmapped while_loop steps every lane
    # until the LAST one finishes, so giving low-rate lanes
    # proportionally longer horizons means each lane-step retires a
    # real event instead of a live-guard no-op.  Summaries normalize by
    # the cell's own final clock, so per-cell metrics are unaffected.
    horizon: jnp.ndarray     # i32 ticks
    long_prob: jnp.ndarray   # f32 long-epoch probability
    long_scale: jnp.ndarray  # f32 long-epoch noncrit scale
    wakeup: jnp.ndarray      # i32 parked-waiter handoff ticks
    # Initial AIMD additive unit (ticks).  Seeded from the *default*
    # window, NOT the carried windows0: a resumed run whose windows
    # collapsed to ~0 must keep a regrowth floor, or zero becomes an
    # absorbing state (window only ever shrinks).
    unit0: jnp.ndarray       # f32 ticks
    # Stochastic workload knobs (all traced; live ops only when cfg.wl)
    wl_process: jnp.ndarray   # i32 ARRIVALS id
    wl_service: jnp.ndarray   # i32 SERVICES id
    wl_rate: jnp.ndarray      # f32 offered-load scale
    wl_cv: jnp.ndarray        # f32 service cv
    wl_mix: jnp.ndarray       # f32 bimodal long-mode probability
    wl_mix_scale: jnp.ndarray  # f32 bimodal long/short ratio
    wl_burst: jnp.ndarray     # f32 MMPP on/off rate ratio
    wl_burst_len: jnp.ndarray  # f32 mean epochs per MMPP phase
    wl_amp: jnp.ndarray       # f32 diurnal amplitude
    wl_period: jnp.ndarray    # f32 diurnal period (ticks)
    # Fault-injection knobs (repro.faults; live ops only when the
    # matching cfg rate's static on/off bit is set)
    preempt_rate: jnp.ndarray    # f32 P(holder preempted) per CS
    preempt_scale: jnp.ndarray   # f32 mean stall (ticks)
    churn_rate: jnp.ndarray      # f32 P(core off) per churn slot
    churn_period: jnp.ndarray    # i32 churn slot length (ticks, >= 1)
    straggle_rate: jnp.ndarray   # f32 P(CS spike)
    straggle_scale: jnp.ndarray  # f32 CS spike multiplier
    # Key-sharded traffic (repro.workloads.keys; live ops only when
    # cfg.n_keys > 0, the static key-shard gate).  The three sampler
    # constants are host-precomputed per cell by zipf_consts — they are
    # pure functions of (ks_keys, ks_theta), carried traced so key-count
    # and exponent sweeps share one executable.
    ks_keys: jnp.ndarray     # i32 active key count
    ks_theta: jnp.ndarray    # f32 Zipf exponent (pole-nudged)
    ks_zeta: jnp.ndarray     # f32 harmonic H_{n,theta}
    ks_eta: jnp.ndarray      # f32 Gray/YCSB eta constant
    ks_alpha: jnp.ndarray    # f32 1/(1-theta)
    ks_locks: jnp.ndarray    # i32 active lock count (<= L padded)
    # Streaming-histogram warmup: per-core sample index below which a
    # sample is NOT bucketed (matches summarize's ring warmup, so ring
    # and histogram quantiles agree on un-wrapped runs).  Traced; dead
    # unless ``cfg.hist``.
    hist_warmup: jnp.ndarray  # i32
    # Policy-owned traced knobs (LockPolicy.init_params; {} for the
    # built-in four) — swept via the policy's declared sweep_axes.
    pol: dict


class SimState(NamedTuple):
    t: jnp.ndarray
    key: jnp.ndarray
    phase: jnp.ndarray        # i32[N]
    t_ready: jnp.ndarray      # i32[N]
    seg: jnp.ndarray          # i32[N]
    epoch_start: jnp.ndarray  # i32[N]
    attempt_t: jnp.ndarray    # i32[N]
    window: jnp.ndarray       # f32[N] (ticks)
    unit: jnp.ndarray         # f32[N]
    scale: jnp.ndarray        # f32[N] current epoch noncrit scale (Bench-3/wl)
    svc_scale: jnp.ndarray    # f32[N] current epoch CS scale (wl service)
    wl_on: jnp.ndarray        # i32[N] MMPP on/off phase bit (wl)
    q: jnp.ndarray            # i32[L,2,N] ring buffers (0=main/big, 1=little)
    q_head: jnp.ndarray       # i32[L,2]
    q_tail: jnp.ndarray       # i32[L,2]
    holder: jnp.ndarray       # i32[L]
    prop_ctr: jnp.ndarray     # i32[L]
    ep_lat: jnp.ndarray       # f32[N,EPCAP] epoch latencies (ticks)
    ep_cnt: jnp.ndarray       # i32[N]
    cs_lat: jnp.ndarray       # f32[N,EPCAP] acquire->release latencies
    cs_cnt: jnp.ndarray       # i32[N]
    events: jnp.ndarray       # i32
    arr_t: jnp.ndarray        # i32[N] next open-loop arrival (wl_open)
    energy: jnp.ndarray       # f32[N] accumulated energy (watt-ticks;
    #                           stays all-zero unless a power table is
    #                           set — the static _energy_on gate)
    cur_lock: jnp.ndarray     # i32[N] this epoch's key-drawn lock (all
    #                           zero unless cfg.n_keys > 0 — _ks_on)
    cur_rw: jnp.ndarray       # f32[N] this epoch's read/write uniform
    #                           (CREW policies; 1.0 = read when unused)
    # Constant-memory streaming latency histograms (cfg.hist gate):
    # log-bucketed u32 counts per metric family, merged across cores /
    # cells / shards / devices by plain summation.  Shape [N, 1] when
    # the gate is off (the leaves exist but stay empty and untouched).
    ep_hist: jnp.ndarray      # u32[N, B] epoch-latency counts
    cs_hist: jnp.ndarray      # u32[N, B] acquire->release counts
    # Policy-owned state slots (LockPolicy.init_state; {} for policies
    # that need none — e.g. shfl's per-lock shuffle counter).
    pol: dict


# --------------------------------------------------------------------------
# Static-arg canonicalization: every field that now rides in SimTables /
# SimParams is wiped from the jit key, so numeric variants share executables.
# --------------------------------------------------------------------------

def _canon(cfg: SimConfig) -> SimConfig:
    n, s = cfg.n_cores, len(cfg.seg_cs_us)
    return dataclasses.replace(
        cfg,
        # Merged mode: the member actually run rides traced in
        # SimParams.pol_id, so ``policy`` is wiped to the set's first
        # member — every cell of a policy sweep shares one executable.
        # (``policy_set`` itself stays: it fixes which handlers are in
        # the HLO.  ``use_pallas`` also stays: a different lowering is
        # a different executable, but never different results.)
        policy=cfg.policy_set[0] if cfg.policy_set else cfg.policy,
        big=(0,) * n, speed_cs=(1.0,) * n, speed_nc=(1.0,) * n,
        seg_noncrit_us=(0.0,) * s, seg_cs_us=(0.0,) * s, seg_lock=(0,) * s,
        inter_epoch_us=0.0, w_big=1.0, prop_n=1, default_window_us=0.0,
        # Only the on/off bit of the mix/wakeup/workload features is
        # static (it gates whether the RNG draw / handoff add exist in
        # the HLO at all); the actual values are traced.
        long_epoch_prob=1.0 if cfg.long_epoch_prob > 0.0 else 0.0,
        long_epoch_scale=1.0,
        wakeup_us=1.0 if cfg.wakeup_us > 0.0 else 0.0,
        wl=bool(cfg.wl or cfg.wl_open), wl_open=bool(cfg.wl_open),
        wl_process="poisson", wl_service="det",
        wl_rate=1.0, wl_cv=1.0, wl_mix=0.0, wl_mix_scale=1.0,
        wl_burst=1.0, wl_burst_len=1.0, wl_amp=0.0, wl_period_us=0.0,
        preempt_rate=1.0 if cfg.preempt_rate > 0.0 else 0.0,
        preempt_scale_us=1.0,
        churn_rate=1.0 if cfg.churn_rate > 0.0 else 0.0,
        churn_period_us=1.0,
        straggle_rate=1.0 if cfg.straggle_rate > 0.0 else 0.0,
        straggle_scale=1.0,
        # Key sharding: one static gate bit (do the per-epoch key draws
        # exist in the HLO?).  The canonical on-value is n_locks, not 1,
        # so the canonicalized config still satisfies the key-count >=
        # lock-count validation; the real count rides in SimParams.
        n_keys=cfg.n_locks if cfg.n_keys > 0 else 0,
        zipf_theta=0.0,
        slo_scale=(), wl_service_per_core=(), fault_mask=(),
        dvfs=(), columns=(),
        # Energy: one static on/off bit (whether the integration ops
        # exist in the HLO at all); the watt values ride in SimTables.
        p_cs=(0.0,) if _energy_on(cfg) else (),
        p_spin=(), p_park=(), p_idle=(),
        # Streaming histograms: ``hist`` is the static gate and
        # ``hist_buckets`` the static state shape (only meaningful when
        # on — wiped to the default otherwise so gate-off configs share
        # executables); the bucket range and warmup ride traced.
        hist_buckets=cfg.hist_buckets if cfg.hist else 512,
        hist_lo_us=1.0, hist_hi_us=2.0, hist_warmup=0,
        policy_kw=())


def _ks_on(cfg: SimConfig) -> bool:
    """The single static key-shard gate: are epochs' locks drawn from
    the Zipf key stream (vs the static segment program)?"""
    return cfg.n_keys > 0


def _energy_on(cfg: SimConfig) -> bool:
    """The single static energy gate: is any per-core power table set?
    (Zero-valued tables still flip it on — they compile the integration
    ops but accumulate exact zeros, which is what the zero-power
    bit-purity probe asserts.)"""
    return bool(cfg.p_cs or cfg.p_spin or cfg.p_park or cfg.p_idle)


def _active_policy(cfg: SimConfig):
    """The policy object the compiled step dispatches through: the
    registered singleton, or — merged mode — the cached
    :class:`~repro.core.policies.MergedPolicy` for ``cfg.policy_set``
    (hooks fan out over members masked on the traced pol_id)."""
    if cfg.policy_set:
        return policies.merged(cfg.policy_set)
    return policies.get(cfg.policy)


def _rw_draw_gate(cfg: SimConfig, pm) -> object:
    """Does THIS run consume the per-epoch read/write uniform?

    Single-policy configs return the policy's Python-literal
    ``uses_rw`` (HLO-preserving: the draw ops only exist when True).
    Merged sets return a traced mask over ``pm.pol_id`` so a non-rw
    cell (e.g. fifo) sharing an executable with ks_crew keeps
    ``cur_rw == 1.0`` bit-identically to its own executable."""
    if not cfg.policy_set:
        return policies.get(cfg.policy).uses_rw
    ids = _active_policy(cfg).rw_member_ids()
    if not ids:
        return False
    m = pm.pol_id == ids[0]
    for pid in ids[1:]:
        m = jnp.logical_or(m, pm.pol_id == pid)
    return m


def _and_gate(cond, gate):
    """cond AND a _rw_draw_gate result (which may be the Python literal
    True on the single-policy path — where the AND must vanish)."""
    return cond if gate is True else jnp.logical_and(cond, gate)


def build_tables(cfg: SimConfig) -> SimTables:
    """Precompute the per-(core, segment) duration tables once per run.

    Every registered :class:`~repro.core.columns.ColumnSpec` is
    materialized into ``SimTables.col`` — encoded, then padded with its
    *neutral default* (a short f32[k] table would be index-*clamped*
    inside jit, silently giving high cores the last entry's value).
    The ``dvfs`` column additionally divides the segment durations
    host-side (frequency scaling; f=1.0 is bitwise exact, so default-
    DVFS tables are bit-identical to pre-DVFS ones).  The inter-epoch
    gap is application pacing, not compute — it stays
    frequency-independent so DVFS sweeps change service capacity, not
    offered load."""
    n = cfg.n_cores
    s = len(cfg.seg_cs_us)
    f = colreg.COLUMNS["dvfs"].host_values(cfg, n)
    col = {spec.name: jnp.asarray(
        spec.host_values(cfg, n),
        jnp.int32 if spec.dtype == "i32" else jnp.float32)
        for spec in colreg.COLUMNS.values()}
    # Streaming-histogram edge parameterization, precomputed host-side
    # in TICKS (the unit latency samples are recorded in).  Always
    # materialized (two dead scalars when cfg.hist is off).
    h_log2_lo, h_inv_log2g = stats.layout(
        cfg.hist_lo_us * US, cfg.hist_hi_us * US, max(cfg.hist_buckets, 4))
    return SimTables(
        big=jnp.asarray(cfg.big[:n], jnp.int32),
        cs_dur=jnp.asarray(
            [[_ticks(cfg.seg_cs_us[j] * cfg.speed_cs[c] / f[c])
              for j in range(s)] for c in range(n)], jnp.int32),
        nc_dur=jnp.asarray(
            [[_ticks(cfg.seg_noncrit_us[j] * cfg.speed_nc[c] / f[c])
              for j in range(s)] for c in range(n)], jnp.int32),
        inter=jnp.asarray(
            [_ticks(cfg.inter_epoch_us * cfg.speed_nc[c]) for c in range(n)],
            jnp.int32),
        seg_lock=jnp.asarray(cfg.seg_lock, jnp.int32),
        hist_log2_lo=jnp.float32(h_log2_lo),
        hist_inv_log2g=jnp.float32(h_inv_log2g),
        col=col)


def table_columns(cfg: SimConfig) -> dict:
    """Host-side view of every registered column exactly as
    ``build_tables`` materializes it (encoded + padded), keyed by
    column name — the host-reconstruction counterpart of
    ``SimTables.col`` (pairs with ``generators.epoch_scale_tables``)."""
    return {spec.name: spec.host_values(cfg, cfg.n_cores)
            for spec in colreg.COLUMNS.values()}


def with_columns(cfg: SimConfig, **cols) -> SimConfig:
    """Set registered per-core columns on a config by *column name*:
    dedicated-field columns (``slo_scale``, ``fault_mask``, ``dvfs``,
    the power tables ...) route to their SimConfig field; plugin-owned
    columns land in the generic ``cfg.columns`` tuple.  Unknown names
    raise with a did-you-mean."""
    for name, vals in cols.items():
        spec = colreg.lookup(name)
        if spec.field:
            cfg = dataclasses.replace(cfg, **{spec.field: tuple(vals)})
        else:
            d = dict(cfg.columns)
            d[name] = tuple(vals)
            cfg = dataclasses.replace(cfg, columns=tuple(sorted(d.items())))
    return cfg


def build_params(cfg: SimConfig, slo_us, seed=0, n_active=None) -> SimParams:
    """SimParams from config defaults (each field is a sweep axis)."""
    pol_params = _active_policy(cfg).init_params(cfg)
    # Every policy_kw key must land in a traced pol slot — a typo'd knob
    # silently running with its default would be the one misconfiguration
    # here that doesn't raise.
    unknown = set(dict(cfg.policy_kw)) - set(pol_params)
    if unknown:
        raise ValueError(
            f"unknown policy_kw {sorted(unknown)} for policy "
            f"{cfg.policy!r}; known knobs: {sorted(pol_params)}")
    slo = (slo_us * US).astype(jnp.float32) if hasattr(slo_us, "astype") \
        else jnp.float32(_ticks(slo_us))
    ks_theta, ks_zeta, ks_eta, ks_alpha = wlk.zipf_consts(
        max(cfg.n_keys, 1), cfg.zipf_theta)
    return SimParams(
        slo=slo,
        pol_id=jnp.int32(POLICIES[cfg.policy]),
        w_big=jnp.float32(cfg.w_big),
        prop_n=jnp.int32(cfg.prop_n),
        n_active=jnp.int32(cfg.n_cores if n_active is None else n_active),
        seed=jnp.int32(seed) if not hasattr(seed, "dtype")
        else seed.astype(jnp.int32),
        horizon=jnp.int32(_ticks(cfg.sim_time_us)),
        long_prob=jnp.float32(cfg.long_epoch_prob),
        long_scale=jnp.float32(cfg.long_epoch_scale),
        wakeup=jnp.int32(_ticks(cfg.wakeup_us)),
        unit0=jnp.float32(aimd.unit_for(_ticks(cfg.default_window_us),
                                        cfg.pct)),
        wl_process=jnp.int32(wlg.ARRIVALS[cfg.wl_process]),
        wl_service=jnp.int32(wlg.SERVICES[cfg.wl_service]),
        wl_rate=jnp.float32(cfg.wl_rate),
        wl_cv=jnp.float32(cfg.wl_cv),
        wl_mix=jnp.float32(cfg.wl_mix),
        wl_mix_scale=jnp.float32(cfg.wl_mix_scale),
        wl_burst=jnp.float32(cfg.wl_burst),
        wl_burst_len=jnp.float32(cfg.wl_burst_len),
        wl_amp=jnp.float32(cfg.wl_amp),
        wl_period=jnp.float32(_ticks(
            cfg.wl_period_us if cfg.wl_period_us > 0.0
            else cfg.sim_time_us)),
        preempt_rate=jnp.float32(cfg.preempt_rate),
        preempt_scale=jnp.float32(_ticks(cfg.preempt_scale_us)),
        churn_rate=jnp.float32(cfg.churn_rate),
        churn_period=jnp.int32(max(_ticks(cfg.churn_period_us), 1)),
        straggle_rate=jnp.float32(cfg.straggle_rate),
        straggle_scale=jnp.float32(cfg.straggle_scale),
        ks_keys=jnp.int32(cfg.n_keys),
        ks_theta=jnp.float32(ks_theta),
        ks_zeta=jnp.float32(ks_zeta),
        ks_eta=jnp.float32(ks_eta),
        ks_alpha=jnp.float32(ks_alpha),
        ks_locks=jnp.int32(cfg.n_locks),
        hist_warmup=jnp.int32(cfg.hist_warmup),
        pol=pol_params)


def _default_windows(cfg: SimConfig) -> np.ndarray:
    return np.full(cfg.n_cores, _ticks(cfg.default_window_us), np.float32)


def _init_state(cfg: SimConfig, tb: SimTables, pm: SimParams,
                windows0) -> SimState:
    n, l, cap = cfg.n_cores, cfg.n_locks, cfg.epcap
    active = jnp.arange(n, dtype=jnp.int32) < pm.n_active
    # Stagger initial arrivals slightly so ties don't all collapse to core 0.
    stagger = jnp.arange(n, dtype=jnp.int32)
    windows0 = jnp.asarray(windows0, jnp.float32)
    if cfg.wl:
        # Epoch-0 workload draws — counter-based (pure in (seed, core, 0)),
        # so padded / batched / sharded runs see identical values.
        cores = jnp.arange(n, dtype=jnp.int32)
        u_t = jax.vmap(lambda c: wlg.epoch_think_u(pm.seed, c, 0))(cores)
        u_s, z_s = jax.vmap(
            lambda c: wlg.epoch_service_uz(pm.seed, c, 0))(cores)
        u_p = jax.vmap(lambda c: wlg.epoch_phase_u(pm.seed, c, 0))(cores)
        wl_on0 = (u_p < 0.5).astype(jnp.int32)
        think0 = wlg.think_gap(u_t, pm.wl_process, pm.wl_rate, wl_on0,
                               pm.wl_burst, 0.0, pm.wl_amp)
        svc0 = wlg.service_unit(u_s, z_s, _svc_dist(tb, pm), pm.wl_cv,
                                pm.wl_mix, pm.wl_mix_scale)
        scale0 = jnp.ones(n, jnp.float32) if cfg.wl_open else think0
        nc0 = (tb.nc_dur[:, 0].astype(jnp.float32)
               * scale0).astype(jnp.int32)
    else:
        wl_on0 = jnp.zeros(n, jnp.int32)
        think0 = scale0 = jnp.ones(n, jnp.float32)
        svc0 = jnp.ones(n, jnp.float32)
        nc0 = tb.nc_dur[:, 0]
    if cfg.wl_open:
        # Open-loop: every core starts parked on its pending-ARRIVAL
        # event.  Arrival 0 is drawn from the same think stream a
        # closed-loop run would consume (gap base = the closed-loop
        # think budget inter+noncrit); the stagger keeps clock ties off
        # core 0 exactly as in closed-loop mode.
        base = (tb.inter + tb.nc_dur[:, 0]).astype(jnp.float32)
        arr0 = jnp.maximum((base * think0).astype(jnp.int32), 1) + stagger
        phase0 = jnp.full(n, ARRIVAL, jnp.int32)
        ready0 = jnp.where(active, arr0, INF)
    else:
        arr0 = jnp.zeros(n, jnp.int32)
        phase0 = jnp.zeros(n, jnp.int32)
        ready0 = jnp.where(active, nc0 + stagger, INF)
    if _ks_on(cfg):
        # Epoch-0 key draws (repro.workloads.keys) — counter-pure in
        # (seed, core, 0) like every workload draw.  Open-loop runs
        # redraw index 0 at the first ARRIVAL event (same value).
        cores = jnp.arange(n, dtype=jnp.int32)
        cur_lock0 = jax.vmap(lambda c: wlk.epoch_lock(
            pm.seed, c, 0, pm.ks_keys, pm.ks_theta, pm.ks_zeta,
            pm.ks_eta, pm.ks_alpha, pm.ks_locks))(cores)
        gate = _rw_draw_gate(cfg, pm)
        if gate is False:
            cur_rw0 = jnp.ones(n, jnp.float32)
        else:
            draws = jax.vmap(
                lambda c: wlk.epoch_rw_u(pm.seed, c, 0))(cores)
            cur_rw0 = draws if gate is True else \
                jnp.where(gate, draws, jnp.ones(n, jnp.float32))
    else:
        cur_lock0 = jnp.zeros(n, jnp.int32)
        cur_rw0 = jnp.ones(n, jnp.float32)
    return SimState(
        t=jnp.int32(0),
        key=jax.random.PRNGKey(pm.seed),
        phase=phase0,
        t_ready=ready0,
        seg=jnp.zeros(n, jnp.int32),
        epoch_start=jnp.zeros(n, jnp.int32),
        attempt_t=jnp.zeros(n, jnp.int32),
        window=windows0,
        unit=jnp.full(n, pm.unit0, jnp.float32),
        q=jnp.full((l, 2, n), -1, jnp.int32),
        q_head=jnp.zeros((l, 2), jnp.int32),
        q_tail=jnp.zeros((l, 2), jnp.int32),
        holder=jnp.full(l, -1, jnp.int32),
        prop_ctr=jnp.zeros(l, jnp.int32),
        scale=scale0,
        svc_scale=svc0,
        wl_on=wl_on0,
        ep_lat=jnp.zeros((n, cap), jnp.float32),
        ep_cnt=jnp.zeros(n, jnp.int32),
        cs_lat=jnp.zeros((n, cap), jnp.float32),
        cs_cnt=jnp.zeros(n, jnp.int32),
        events=jnp.int32(0),
        arr_t=arr0,
        ep_hist=jnp.zeros((n, cfg.hist_buckets if cfg.hist else 1),
                          jnp.uint32),
        cs_hist=jnp.zeros((n, cfg.hist_buckets if cfg.hist else 1),
                          jnp.uint32),
        energy=jnp.zeros(n, jnp.float32),
        cur_lock=cur_lock0,
        cur_rw=cur_rw0,
        pol=_active_policy(cfg).init_state(cfg, tb, pm),
    )


def init_state(cfg: SimConfig, seed: int = 0, windows0=None) -> SimState:
    """Back-compat single-run initializer."""
    tb = build_tables(cfg)
    pm = build_params(cfg, 0.0, seed)
    w0 = _default_windows(cfg) if windows0 is None else windows0
    return _init_state(cfg, tb, pm, w0)


# --------------------------------------------------------------------------
# Event handlers.
#
# Every handler is *fully conditional*: it takes a ``cond`` and commits no
# state when it is false.  The single-run path dispatches via ``lax.switch``
# with ``cond=True`` (the masks constant-fold away, so it pays nothing);
# the batched sweep path applies all handlers as one branchless masked step
# so ``vmap`` lowers to in-place batched scatters instead of
# select-over-every-branch full-state copies.
# ``cond`` must only be combined via logical_and/where (it may be the
# Python literal True on the switch path).
#
# Policy decisions live in repro.core.policies plugins; the handlers here
# are policy-agnostic (they dispatch the registry hooks — no policy-name
# branches).  Queue/grant/pick helpers are shared with the policies via
# repro.core.policies.base (re-exported above under their old names).
# --------------------------------------------------------------------------

def _svc_dist(tb: SimTables, pm: SimParams, c=None):
    """Effective SERVICES id: the per-core table override (multi-class
    tenants), falling back to the run-wide traced id."""
    per_core = tb.col["wl_service"] if c is None else tb.col["wl_service"][c]
    return jnp.where(per_core >= 0, per_core, pm.wl_service)


def _power_draw(tb: SimTables, pm: SimParams, st: SimState):
    """Per-core instantaneous watts from phase + DVFS state: compute
    (NONCRIT/HOLDER) and busy-wait (SPIN/STANDBY) draws scale with
    dvfs^3 (P_dyn ~ f^3, the DVFS cube law); parked (QUEUED) and idle
    (ARRIVAL wait) are frequency-independent floor draws.  Inactive
    padded cores draw idle power."""
    ph = st.phase
    f3 = tb.col["dvfs"] ** 3
    p = jnp.where(
        jnp.logical_or(ph == NONCRIT, ph == HOLDER), tb.col["p_cs"] * f3,
        jnp.where(jnp.logical_or(ph == SPIN, ph == STANDBY),
                  tb.col["p_spin"] * f3,
                  jnp.where(ph == QUEUED, tb.col["p_park"],
                            tb.col["p_idle"])))
    active = jnp.arange(ph.shape[0], dtype=jnp.int32) < pm.n_active
    return jnp.where(active, p, tb.col["p_idle"])


def _handle_acquire(st: SimState, cfg: SimConfig, tb: SimTables,
                    pm: SimParams, c, t, cond) -> SimState:
    """A core's non-critical section ended: record the attempt time and
    let the policy decide grab / queue / standby / spin."""
    if cfg.churn_rate > 0.0:
        # Core churn: during an "off" slot the core is descheduled — the
        # acquire attempt bounces to the next slot boundary (strictly
        # future, so churn can never deadlock) and the policy never sees
        # it.  One counter-pure decision per (core, slot); the rate is
        # multiplied by the per-core eligibility mask so an ineligible
        # core (or rate 0) is bit-identical to fault-free.
        off = flt.churn_off(pm.seed, c, t,
                            pm.churn_rate * tb.col["ft_mask"][c],
                            pm.churn_period)
        bounce = jnp.logical_and(cond, off)
        st = st._replace(t_ready=st.t_ready.at[c].set(
            jnp.where(bounce, flt.churn_rejoin(t, pm.churn_period),
                      st.t_ready[c])))
        cond = jnp.logical_and(cond, jnp.logical_not(off))
    st = st._replace(attempt_t=st.attempt_t.at[c].set(
        jnp.where(cond, t, st.attempt_t[c])))
    return _active_policy(cfg).on_acquire(st, cfg, tb, pm, c, t, cond)


def _record(buf, cnt, c, value, cond):
    cap = buf.shape[1]
    pos = cnt[c] % cap
    val = jnp.where(cond, value, buf[c, pos])
    return buf.at[c, pos].set(val), cnt.at[c].add(jnp.where(cond, 1, 0))


def _hist_record(hist, tb: SimTables, c, value, cond):
    """Scatter one latency sample (ticks) into core ``c``'s log-bucketed
    histogram row: one log2, one clipped floor, one masked add — fully
    conditional like every handler op (``cond`` False commits nothing).
    Bucket layout lives in repro.core.stats; the two edge scalars are
    host-precomputed in SimTables."""
    nb = hist.shape[1]
    lg = (jnp.log2(jnp.maximum(value, jnp.float32(1e-6)))
          - tb.hist_log2_lo) * tb.hist_inv_log2g
    idx = jnp.clip(1 + jnp.floor(lg).astype(jnp.int32), 0, nb - 1)
    return hist.at[c, idx].add(
        jnp.where(cond, jnp.uint32(1), jnp.uint32(0)))


def _handle_arrival(st: SimState, cfg: SimConfig, tb: SimTables,
                    pm: SimParams, c, t, cond) -> SimState:
    """Open-loop mode (``wl_open``): the pending-ARRIVAL event fired.

    Begin the epoch at its *true* arrival time ``arr_t[c]`` (which may be
    in the past when the core is backlogged — epoch latency then includes
    the queueing delay, the open-loop load-latency knee), and draw the
    next arrival gap from the workload's arrival process.  Draws are
    counter-pure in (seed, core, arrival index), so sweeps, sharding and
    event interleaving cannot perturb the arrival stream."""
    a = st.arr_t[c]
    nxt_ix = st.ep_cnt[c] + 1          # arrivals consumed so far + 1
    u_t = wlg.epoch_think_u(pm.seed, c, nxt_ix)
    u_p = wlg.epoch_phase_u(pm.seed, c, nxt_ix)
    on = wlg.phase_flip(u_p, st.wl_on[c], pm.wl_burst_len)
    phase01 = jnp.mod(t.astype(jnp.float32)
                      / jnp.maximum(pm.wl_period, 1.0), 1.0)
    gap = wlg.think_gap(u_t, pm.wl_process, pm.wl_rate, on,
                        pm.wl_burst, phase01, pm.wl_amp)
    base = (tb.inter[c] + tb.nc_dur[c, 0]).astype(jnp.float32)
    nxt = a + jnp.maximum((base * gap).astype(jnp.int32), 1)
    nc0 = (tb.nc_dur[c, 0].astype(jnp.float32)
           * st.scale[c]).astype(jnp.int32)
    if _ks_on(cfg):
        # The epoch starting at this arrival touches key index
        # ep_cnt[c] (arrival i begins epoch i) — counter-pure, so the
        # key stream is independent of backlog and event interleaving.
        ep = st.ep_cnt[c]
        lk = wlk.epoch_lock(pm.seed, c, ep, pm.ks_keys, pm.ks_theta,
                            pm.ks_zeta, pm.ks_eta, pm.ks_alpha,
                            pm.ks_locks)
        st = st._replace(cur_lock=st.cur_lock.at[c].set(
            jnp.where(cond, lk, st.cur_lock[c])))
        gate = _rw_draw_gate(cfg, pm)
        if gate is not False:
            rw = wlk.epoch_rw_u(pm.seed, c, ep)
            st = st._replace(cur_rw=st.cur_rw.at[c].set(
                jnp.where(_and_gate(cond, gate), rw, st.cur_rw[c])))
    return st._replace(
        arr_t=st.arr_t.at[c].set(jnp.where(cond, nxt, st.arr_t[c])),
        wl_on=st.wl_on.at[c].set(jnp.where(cond, on, st.wl_on[c])),
        epoch_start=st.epoch_start.at[c].set(
            jnp.where(cond, a, st.epoch_start[c])),
        phase=st.phase.at[c].set(jnp.where(cond, NONCRIT, st.phase[c])),
        t_ready=st.t_ready.at[c].set(
            jnp.where(cond, t + nc0, st.t_ready[c])))


def _handle_release(st: SimState, cfg: SimConfig, tb: SimTables,
                    pm: SimParams, c, t, cond) -> SimState:
    pol = _active_policy(cfg)
    s = st.seg[c]
    l = _lock_of(st, cfg, tb, c)    # key-drawn lock when _ks_on, else
    n_seg = len(cfg.seg_cs_us)      # the static segment program's

    # acquire->release latency (paper Figure 1 metric)
    cs_latency = (t - st.attempt_t[c]).astype(jnp.float32)
    if cfg.hist:
        # Streaming histogram (pre-increment count = this sample's
        # index; gated on the traced warmup so histogram and ring
        # quantiles agree on un-wrapped runs).
        st = st._replace(cs_hist=_hist_record(
            st.cs_hist, tb, c, cs_latency,
            jnp.logical_and(cond, st.cs_cnt[c] >= pm.hist_warmup)))
    cs_lat, cs_cnt = _record(st.cs_lat, st.cs_cnt, c, cs_latency, cond)
    st = st._replace(cs_lat=cs_lat, cs_cnt=cs_cnt)

    last = s == n_seg - 1
    # Epoch end: record latency; the policy runs its feedback (e.g.
    # LibASL's AIMD window update — little cores only).
    ep_latency = (t - st.epoch_start[c]).astype(jnp.float32)
    ep_cond = jnp.logical_and(last, cond)
    if cfg.hist:
        st = st._replace(ep_hist=_hist_record(
            st.ep_hist, tb, c, ep_latency,
            jnp.logical_and(ep_cond, st.ep_cnt[c] >= pm.hist_warmup)))
    ep_lat, ep_cnt = _record(st.ep_lat, st.ep_cnt, c, ep_latency, ep_cond)
    st = st._replace(ep_lat=ep_lat, ep_cnt=ep_cnt)

    st = pol.on_release(st, cfg, tb, pm, c, t, ep_latency, last, cond)

    # Sample the next epoch's workload: the Bench-3 long-epoch mix and/or
    # the repro.workloads stochastic model.  Both are statically gated on
    # their canonicalized on/off bits — the RNG draws only exist in the
    # HLO when the feature is enabled; all values are traced (sweepable).
    new_scale = None
    if cfg.long_epoch_prob > 0.0:
        key, sub = jax.random.split(st.key)
        u = jax.random.uniform(sub)
        new_scale = jnp.where(u < pm.long_prob, pm.long_scale,
                              jnp.float32(1.0))
        st = st._replace(key=jnp.where(cond, key, st.key))
    if cfg.wl:
        # Counter-based draws (repro.workloads.generators): pure in
        # (seed, core, epoch-index), so batching/sharding/event order
        # cannot perturb the workload, and the host can reconstruct it
        # (generators.epoch_scale_tables).  st.ep_cnt[c] was already
        # bumped above, so it is the *next* epoch's index.
        ep = st.ep_cnt[c]
        u_s, z_s = wlg.epoch_service_uz(pm.seed, c, ep)
        svc = wlg.service_unit(u_s, z_s, _svc_dist(tb, pm, c), pm.wl_cv,
                               pm.wl_mix, pm.wl_mix_scale)
        upd = jnp.logical_and(last, cond)
        st = st._replace(svc_scale=st.svc_scale.at[c].set(
            jnp.where(upd, svc, st.svc_scale[c])))
        if not cfg.wl_open:
            # Closed loop: the think draw scales the next epoch's
            # non-critical segments.  (Open loop consumes the think
            # stream in _handle_arrival instead — as arrival gaps.)
            u_t = wlg.epoch_think_u(pm.seed, c, ep)
            u_p = wlg.epoch_phase_u(pm.seed, c, ep)
            on = wlg.phase_flip(u_p, st.wl_on[c], pm.wl_burst_len)
            phase01 = jnp.mod(t.astype(jnp.float32)
                              / jnp.maximum(pm.wl_period, 1.0), 1.0)
            think = wlg.think_gap(u_t, pm.wl_process, pm.wl_rate, on,
                                  pm.wl_burst, phase01, pm.wl_amp)
            new_scale = think if new_scale is None else new_scale * think
            st = st._replace(
                wl_on=st.wl_on.at[c].set(jnp.where(upd, on, st.wl_on[c])))
    if new_scale is not None:
        scale_c = jnp.where(jnp.logical_and(last, cond), new_scale,
                            st.scale[c])
        st = st._replace(scale=st.scale.at[c].set(scale_c))

        def _sc(d):
            return (d.astype(jnp.float32) * scale_c).astype(jnp.int32)
    else:
        def _sc(d):
            return d

    if _ks_on(cfg) and not cfg.wl_open:
        # Closed loop: draw the NEXT epoch's key at epoch end (ep_cnt
        # was bumped above, so it is the next epoch's index; epoch 0 was
        # drawn in _init_state).  Open loop draws at the true arrival in
        # _handle_arrival instead.  Updating cur_lock here is safe: the
        # releaser's old lock ``l`` was captured above, and the waiter
        # scans in pick_next never include the releaser (it is not
        # parked).
        ep = st.ep_cnt[c]
        upd = jnp.logical_and(last, cond)
        lk = wlk.epoch_lock(pm.seed, c, ep, pm.ks_keys, pm.ks_theta,
                            pm.ks_zeta, pm.ks_eta, pm.ks_alpha,
                            pm.ks_locks)
        st = st._replace(cur_lock=st.cur_lock.at[c].set(
            jnp.where(upd, lk, st.cur_lock[c])))
        gate = _rw_draw_gate(cfg, pm)
        if gate is not False:
            rw = wlk.epoch_rw_u(pm.seed, c, ep)
            st = st._replace(cur_rw=st.cur_rw.at[c].set(
                jnp.where(_and_gate(upd, gate), rw, st.cur_rw[c])))

    # Advance the program: next segment, or — epoch done — the closed-loop
    # think gap (inter-epoch + segment-0 noncrit), or the open-loop
    # pending-ARRIVAL event at the next arrival (possibly already past).
    s_next = jnp.where(last, 0, s + 1)
    mid_ready = t + _sc(tb.nc_dur[c, jnp.minimum(s + 1, n_seg - 1)])
    if cfg.wl_open:
        ep_start_next = st.epoch_start[c]      # set by _handle_arrival
        ready = jnp.where(last, jnp.maximum(t, st.arr_t[c]), mid_ready)
        phase_next = jnp.where(last, ARRIVAL, NONCRIT)
    else:
        ep_start_next = jnp.where(last, t + _sc(tb.inter[c]),
                                  st.epoch_start[c])
        ready = jnp.where(last,
                          t + _sc(tb.inter[c]) + _sc(tb.nc_dur[c, 0]),
                          mid_ready)
        phase_next = jnp.int32(NONCRIT)
    st = st._replace(
        seg=st.seg.at[c].set(jnp.where(cond, s_next, st.seg[c])),
        epoch_start=st.epoch_start.at[c].set(
            jnp.where(cond, ep_start_next, st.epoch_start[c])),
        phase=st.phase.at[c].set(jnp.where(cond, phase_next, st.phase[c])),
        t_ready=st.t_ready.at[c].set(jnp.where(cond, ready, st.t_ready[c])))

    # Hand the lock over.
    st = st._replace(holder=st.holder.at[l].set(
        jnp.where(cond, -1, st.holder[l])))
    return pol.pick_next(st, cfg, tb, pm, l, t, cond)


# --------------------------------------------------------------------------
# Main loop
# --------------------------------------------------------------------------

def _dispatch_table(cfg: SimConfig):
    """Phase id -> handler, built per trace from the registry policy.

    The table is the single source of event dispatch for both step modes:
    phases a config cannot reach (STANDBY without ``uses_standby``,
    ARRIVAL without ``wl_open``) are simply absent, so their handlers
    never enter the compiled HLO."""
    pol = _active_policy(cfg)
    table = [(NONCRIT, _handle_acquire), (HOLDER, _handle_release)]
    if pol.uses_standby:
        table.append((STANDBY, lambda st, cfg, tb, pm, c, t, cond:
                      pol.on_standby_expiry(st, cfg, tb, pm, c, t, cond)))
    if cfg.wl_open:
        table.append((ARRIVAL, _handle_arrival))
    return table


def _step(cfg: SimConfig, tb: SimTables, pm: SimParams, horizon,
          st: SimState, masked: bool) -> SimState:
    """One event — or nothing, when the run is already past its horizon
    (`live` guard: lets a fixed-size scan chunk retire a partial tail).

    ``masked=False``: dispatch one handler via ``lax.switch`` (cheapest for
    a single run).  ``masked=True``: apply every handler under its phase
    mask — branchless, so a ``vmap`` over sweep lanes lowers to batched
    in-place scatters instead of per-branch full-state selects."""
    c = jnp.argmin(st.t_ready).astype(jnp.int32)
    t = st.t_ready[c]                       # == min(t_ready)
    live = jnp.logical_and(t < horizon, st.events < cfg.max_events)
    if _energy_on(cfg):
        # Energy integrates exactly over global time: this event
        # advances the clock st.t -> t, and every core spends that dt
        # in its *current* phase.  The update is passive (reads state,
        # perturbs nothing downstream) and statically gated, so
        # power-free runs compile no energy ops and zero-power runs
        # accumulate exact zeros.
        dt = jnp.where(live, (t - st.t).astype(jnp.float32),
                       jnp.float32(0.0))
        st = st._replace(energy=st.energy + dt * _power_draw(tb, pm, st))
    st = st._replace(t=jnp.where(live, t, st.t),
                     events=st.events + jnp.where(live, 1, 0))
    table = _dispatch_table(cfg)

    if masked:
        ph = st.phase[c]
        for phase, fn in table:
            st = fn(st, cfg, tb, pm, c, t,
                    jnp.logical_and(live, ph == phase))
        # QUEUED/SPIN at the head of the clock: defensive re-park.
        park = jnp.logical_and(live, jnp.logical_or(ph == QUEUED, ph == SPIN))
        return st._replace(t_ready=st.t_ready.at[c].set(
            jnp.where(park, INF, st.t_ready[c])))

    def noop(s):
        return s._replace(t_ready=s.t_ready.at[c].set(INF))

    def dead(s):
        return s

    def bind(fn):
        return lambda s: fn(s, cfg, tb, pm, c, t, True)

    by_phase = dict(table)
    n_phases = ARRIVAL + 1
    branches = [bind(by_phase[p]) if p in by_phase else noop
                for p in range(n_phases)] + [dead]
    branch = jnp.where(live, st.phase[c], n_phases)
    return jax.lax.switch(branch, branches, st)


def _simulate(cfg: SimConfig, tb: SimTables, pm: SimParams,
              windows0, masked: bool = False) -> SimState:
    st = _init_state(cfg, tb, pm, windows0)
    horizon = pm.horizon

    def cond(s):
        return jnp.logical_and(jnp.min(s.t_ready) < horizon,
                               s.events < cfg.max_events)

    if cfg.use_pallas:
        # Fused path (repro.kernels.simstep): the whole chunk retires
        # inside one Pallas kernel with the packed state VMEM-resident.
        # Same _step closure -> bit-identical to the jnp body below.
        from repro.kernels import simstep

        def body(s):
            return simstep.fused_chunk(
                lambda t_, p_, s_: _step(cfg, t_, p_, horizon, s_, masked),
                tb, pm, s, cfg.chunk)

        return jax.lax.while_loop(cond, body, st)

    def body(s):
        def chunk_step(s, _):
            return _step(cfg, tb, pm, horizon, s, masked), None
        return jax.lax.scan(chunk_step, s, None, length=max(cfg.chunk, 1))[0]

    return jax.lax.while_loop(cond, body, st)


@partial(jax.jit, static_argnums=(0,), donate_argnums=(3,))
def _run_single(ccfg: SimConfig, tb: SimTables, pm: SimParams, windows0):
    return _simulate(ccfg, tb, pm, windows0, masked=False)


# --------------------------------------------------------------------------
# Batched executables: AOT-compiled (lower -> compile -> call) instead of a
# plain jit so every executable's accounting — XLA FLOPs/bytes and the
# collective schedule of mesh-sharded sweeps — is captured at compile time
# (benchmarks/simperf.py records it next to wall-clock per figure).
# Cache key = (canon cfg, arg shapes/dtypes/shardings): the same one-
# executable-per-(policy, program) discipline as the jit it replaces.
# --------------------------------------------------------------------------

_BATCH_EXECS: dict = {}          # key -> (compiled, record)
_BATCH_LOCK = threading.Lock()   # dict access only; compiles overlap


def _leaf_sig(x):
    sh = x.sharding if isinstance(x, jax.Array) else None
    return (tuple(x.shape), jnp.dtype(x.dtype).name, sh)


def _batch_executable(ccfg: SimConfig, tb: SimTables, pm: SimParams,
                      windows0):
    key = (ccfg, tuple(_leaf_sig(x)
                       for x in jax.tree.leaves((tb, pm, windows0))))
    with _BATCH_LOCK:
        hit = _BATCH_EXECS.get(key)
    if hit is None:
        def run(t, p, w):
            """All leaves carry a leading sweep-cell axis.  The masked
            (branchless) step keeps the vmap scatter-shaped — a vmapped
            ``lax.switch`` would select over every branch's full state."""
            return jax.vmap(
                lambda a, b, c: _simulate(ccfg, a, b, c, masked=True))(
                    t, p, w)
        # NO donation here (unlike _run_single, where bench2's window
        # carry makes it worth it): the windows0 buffer is tiny, and
        # donating it lets the output `window` leaf alias an input whose
        # host memory XLA CPU occasionally reuses while a *different*
        # executable (e.g. a mesh-sharded sweep) runs concurrently —
        # observed as flaky single-leaf corruption of async results.
        compiled = jax.jit(run).lower(tb, pm, windows0).compile()
        rec = executable_stats(compiled)
        rec["n_cells"] = int(np.shape(pm.slo)[0])
        rec["devices"] = max((x.sharding.num_devices
                              for x in jax.tree.leaves((tb, pm, windows0))
                              if isinstance(x, jax.Array)), default=1)
        with _BATCH_LOCK:
            hit = _BATCH_EXECS.setdefault(key, (compiled, rec))
    return hit


def n_batch_executables() -> int:
    """Distinct batched-sweep executables compiled so far (perf protocol:
    fig1's 24 cells must stay at 3 — one per policy)."""
    return len(_BATCH_EXECS)


def executable_records() -> list:
    """Per-executable accounting records in compile order: XLA flops /
    bytes_accessed, the collective schedule (nonzero only for mesh-sharded
    sweeps), cell count and device count."""
    with _BATCH_LOCK:
        return [rec for _, rec in _BATCH_EXECS.values()]


_SWEEP_LOG: list = []
MAX_SWEEP_LOG = 4096


def _log_sweep(rec: dict) -> None:
    with _BATCH_LOCK:
        _SWEEP_LOG.append(rec)
        if len(_SWEEP_LOG) > MAX_SWEEP_LOG:  # bound long-lived processes
            del _SWEEP_LOG[:-MAX_SWEEP_LOG]


def sweep_log() -> list:
    """One record per :func:`sweep` call (cache hits included) — lets the
    bench attribute executable accounting to the figure that ran it.
    Holds the most recent ``MAX_SWEEP_LOG`` calls; slice-by-snapshot-index
    consumers (benchmarks/simperf.py) are stable as long as fewer than
    that many sweeps happen between snapshot and read."""
    with _BATCH_LOCK:
        return list(_SWEEP_LOG)


def run(cfg: SimConfig, slo_us, seed=0, windows0=None) -> SimState:
    """Run one simulation; slo_us/seed may be traced scalars.
    ``windows0`` carries AIMD state across phases (Bench-2) and is DONATED —
    pass a fresh array (reuse the returned ``state.window`` instead)."""
    tb = build_tables(cfg)
    pm = build_params(cfg, slo_us, seed)
    w0 = _default_windows(cfg) if windows0 is None else \
        jnp.asarray(windows0, jnp.float32)
    return _run_single(_canon(cfg), tb, pm, w0)


# --------------------------------------------------------------------------
# Batched sweeps: one compiled executable for a whole figure
# --------------------------------------------------------------------------

# axis name -> SimParams field (values in natural units; converted below)
_PARAM_AXES = {
    "slo_us": "slo",
    "w_big": "w_big",
    "prop_n": "prop_n",
    "seed": "seed",
    "n_cores": "n_active",
    "long_epoch_prob": "long_prob",
    "long_epoch_scale": "long_scale",
    "wakeup_us": "wakeup",
    # Stochastic workload axes (repro.workloads; require cfg.wl — sweep()
    # flips the static bit on automatically when one is present)
    "arrival_rate": "wl_rate",
    "cv": "wl_cv",
    "mix": "wl_mix",
    "mix_scale": "wl_mix_scale",
    "burstiness": "wl_burst",
    "burst_len": "wl_burst_len",
    # Fault-injection axes (repro.faults; sweep() flips the matching
    # static rate gate on when the axis has a nonzero value)
    "preempt_rate": "preempt_rate",
    "preempt_scale": "preempt_scale",
    "churn_rate": "churn_rate",
    "straggle_rate": "straggle_rate",
    "straggle_scale": "straggle_scale",
    # Key-sharded datastore axes (repro.workloads.keys; require
    # cfg.n_keys > 0 — sweep() flips the static gate on automatically
    # when the n_keys axis is present).  n_locks cells run against the
    # padded cfg.n_locks vectors with the effective count traced in
    # SimParams.ks_locks, mirroring the n_cores active-mask trick.
    "n_keys": "ks_keys",
    "zipf_theta": "ks_theta",
    "n_locks": "ks_locks",
}
_WL_AXES = ("arrival_rate", "cv", "mix", "mix_scale", "burstiness",
            "burst_len")
_KS_AXES = ("n_keys", "zipf_theta", "n_locks")
# Statically-gated features: sweeping the axis must flip the gate field
# on in the template config (the on/off bit is part of the jit key).
_GATE_AXES = ("long_epoch_prob", "wakeup_us", "preempt_rate",
              "churn_rate", "straggle_rate")
# Program axes: SimConfig fields rebuilt through build_tables per cell.
_PROGRAM_AXES = ("seg_noncrit_us", "seg_cs_us", "seg_lock",
                 "inter_epoch_us", "big", "speed_cs", "speed_nc")


def table_axes() -> tuple:
    """Axes that rebuild ``SimTables`` per cell (still one executable):
    the program axes plus every *registered* sweepable column's axis
    name (repro.core.columns) — recomputed so late-registered plugin
    columns sweep without touching the engine."""
    return _PROGRAM_AXES + tuple(colreg.axis_to_spec())


def _sweepable() -> tuple:
    # "policy" is the merged-executable axis: string-valued, dispatched
    # on the traced SimParams.pol_id (sweep() builds the policy_set).
    # "sim_time_us" rides traced in SimParams.horizon — per-cell
    # durations inside one executable (the step-utilization lever).
    return tuple(_PARAM_AXES) + table_axes() + (
        "window0_us", "policy", "sim_time_us")


# Import-time snapshot for docs/introspection; sweep() itself recomputes.
SWEEPABLE = _sweepable()


def sweepable_axes(cfg: SimConfig) -> tuple:
    """All sweep axes valid for ``cfg`` — the engine's plus the
    registered policy's declared ``sweep_axes``."""
    base = _sweepable()
    return base + tuple(
        a for a in _active_policy(cfg).sweep_axes if a not in base)


def _cell_tables_cfg(cfg: SimConfig, cell: dict, table_keys) -> SimConfig:
    """Apply a cell's table-axis values onto the template config:
    program axes replace their field directly; column axes route
    through ``with_columns`` (field-backed or plugin-owned alike)."""
    by_axis = colreg.axis_to_spec()
    for k in table_keys:
        if k in _PROGRAM_AXES:
            cfg = dataclasses.replace(cfg, **{k: cell[k]})
        else:
            v = cell[k]
            cfg = with_columns(cfg, **{by_axis[k].name: tuple(v)})
    return cfg


def _cell_params(cfg: SimConfig, cell: dict, slo_us, seed) -> SimParams:
    pm = build_params(cfg, cell.get("slo_us", slo_us),
                      cell.get("seed", seed),
                      n_active=cell.get("n_cores", cfg.n_cores))
    if "policy" in cell:
        pm = pm._replace(pol_id=jnp.int32(POLICIES[cell["policy"]]))
    if "sim_time_us" in cell:
        pm = pm._replace(horizon=jnp.int32(_ticks(cell["sim_time_us"])))
    if "w_big" in cell:
        pm = pm._replace(w_big=jnp.float32(cell["w_big"]))
    if "prop_n" in cell:
        pm = pm._replace(prop_n=jnp.int32(cell["prop_n"]))
    if "long_epoch_prob" in cell:
        pm = pm._replace(long_prob=jnp.float32(cell["long_epoch_prob"]))
    if "long_epoch_scale" in cell:
        pm = pm._replace(long_scale=jnp.float32(cell["long_epoch_scale"]))
    if "wakeup_us" in cell:
        pm = pm._replace(wakeup=jnp.int32(_ticks(cell["wakeup_us"])))
    for axis in _WL_AXES:
        if axis in cell:
            pm = pm._replace(
                **{_PARAM_AXES[axis]: jnp.float32(cell[axis])})
    for axis in ("preempt_rate", "churn_rate", "straggle_rate",
                 "straggle_scale"):
        if axis in cell:
            pm = pm._replace(**{axis: jnp.float32(cell[axis])})
    if "preempt_scale" in cell:
        pm = pm._replace(preempt_scale=jnp.float32(
            _ticks(cell["preempt_scale"])))
    if any(a in cell for a in _KS_AXES):
        # n_keys / zipf_theta change the Zipf sampler constants, which
        # are host-derived (repro.workloads.keys.zipf_consts) — rebuild
        # the whole constant block so every cell's traced values agree
        # with what build_params would produce for that config.
        nk = int(cell.get("n_keys", cfg.n_keys))
        th = float(cell.get("zipf_theta", cfg.zipf_theta))
        ks_th, ks_ze, ks_et, ks_al = wlk.zipf_consts(max(nk, 1), th)
        pm = pm._replace(
            ks_keys=jnp.int32(nk), ks_theta=jnp.float32(ks_th),
            ks_zeta=jnp.float32(ks_ze), ks_eta=jnp.float32(ks_et),
            ks_alpha=jnp.float32(ks_al),
            ks_locks=jnp.int32(cell.get("n_locks", cfg.n_locks)))
    if "window0_us" in cell:
        # A swept initial window plays the role of default_window_us (the
        # seed's LibASL-MAX cells set both), so the unit floor follows it.
        pm = pm._replace(unit0=jnp.float32(
            aimd.unit_for(_ticks(cell["window0_us"]), cfg.pct)))
    # Policy-declared axes land in the traced SimParams.pol slots (the
    # built-in fields above are already covered by _PARAM_AXES).
    for axis, slot in _active_policy(cfg).sweep_axes.items():
        if axis in cell and slot in pm.pol:
            pm = pm._replace(pol=dict(pm.pol, **{
                slot: jnp.asarray(cell[axis], pm.pol[slot].dtype)}))
    return pm


def _sweep_resumable(ccfg: SimConfig, tb: SimTables, pm: SimParams, w0,
                     resume_dir, chunk: int) -> SimState:
    """Run the batched sweep in ``chunk``-cell slices, checkpointing
    each completed slice atomically (repro.ckpt.checkpointer) so an
    interrupted long sweep resumes from the last completed chunk
    instead of recomputing from cell 0.  Per-cell results are
    bit-identical to the one-shot path: vmap lanes are independent (the
    live-guard no-ops finished lanes), so slicing the cell axis cannot
    perturb any cell's trajectory."""
    import json
    from pathlib import Path

    from repro.ckpt import checkpointer as ckpt

    n_cells = int(np.shape(pm.slo)[0])
    chunk = max(int(chunk), 1)
    bounds = [(lo, min(lo + chunk, n_cells))
              for lo in range(0, n_cells, chunk)]
    # Fingerprint the sweep: resuming into a directory holding a
    # different config/grid would silently splice unrelated results.
    # The digest covers the actual traced values (two grids with equal
    # shapes but different cells must not match).
    import hashlib
    h = hashlib.sha256()
    for x in jax.tree.leaves((tb, pm, w0)):
        h.update(np.ascontiguousarray(np.asarray(x)).tobytes())
    # The digest already covers every traced value — SimTables.col
    # leaves (column drift) and SimParams.pol leaves (policy_kw drift)
    # included; the explicit name lists catch key-set changes whose
    # values happen to collide.
    fp = {"canon": repr(ccfg), "n_cells": n_cells, "chunk": chunk,
          "digest": h.hexdigest(),
          "columns": sorted(tb.col), "pol": sorted(pm.pol),
          "leaves": [[list(np.shape(x)), jnp.dtype(x.dtype).name]
                     for x in jax.tree.leaves((tb, pm))]}
    d = Path(resume_dir)
    d.mkdir(parents=True, exist_ok=True)
    fp_path = d / "sweep.json"
    if fp_path.exists():
        if json.loads(fp_path.read_text()) != fp:
            raise ValueError(
                f"resume_dir {str(resume_dir)!r} holds a different sweep "
                f"(config or grid changed); use a fresh directory")
    else:
        fp_path.write_text(json.dumps(fp))
    done = ckpt.latest_step(d)          # chunks 0..done are on disk
    parts = []
    for k, (lo, hi) in enumerate(bounds):
        tb_k = jax.tree.map(lambda x: x[lo:hi], tb)
        pm_k = jax.tree.map(lambda x: x[lo:hi], pm)
        w_k = w0[lo:hi]
        if done is not None and k <= done:
            target = jax.eval_shape(
                lambda a, b, c: jax.vmap(
                    lambda x, y, z: _simulate(ccfg, x, y, z, masked=True)
                )(a, b, c), tb_k, pm_k, w_k)
            parts.append(ckpt.restore(d, k, target))
            continue
        compiled, rec = _batch_executable(ccfg, tb_k, pm_k, w_k)
        _log_sweep(rec)
        st_k = compiled(tb_k, pm_k, w_k)
        ckpt.save(d, k, st_k)
        parts.append(st_k)
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)


def sweep(cfg: SimConfig, axes: dict, *, slo_us=1e9, seed=0,
          windows0=None, product: bool = True,
          mesh=None, data_axis="data",
          resume_dir=None, resume_chunk: int = 8):
    """Run a whole parameter sweep as ONE vmapped, compiled call.

    ``axes`` maps axis names (see ``SWEEPABLE``) to value lists.  With
    ``product=True`` (default) the grid is the cross-product in the dict's
    key order; with ``product=False`` all lists must have equal length and
    are zipped (pre-flattened grids, e.g. paired slo/window cells).

    ``n_cores`` cells run padded to ``cfg.n_cores`` with an active-core
    mask — identical results to an unpadded run, one executable for all.

    ``mesh`` (a ``jax.sharding.Mesh``) shards the cell dimension over the
    mesh's ``data_axis`` (``repro.dist.sharding.build_sweep_rules``); cells
    are padded to the next multiple of the shard count (duplicates of the
    last cell, trimmed from the result), so every device carries an equal
    contiguous row split and results stay bit-identical to the unsharded
    run (docs/simulator.md §Sharded sweeps).

    ``resume_dir`` makes a long sweep resumable: cells run in
    ``resume_chunk``-sized slices, each checkpointed atomically on
    completion (``repro.ckpt.checkpointer``); re-running the same sweep
    with the same directory restores completed chunks and continues,
    bit-identical to an uninterrupted run.  Not composable with
    ``mesh``.

    Returns ``(state, grid)``: ``state`` leaves have a leading cell axis;
    ``grid`` maps axis name -> np.ndarray of per-cell values.  Non-swept
    values come from ``cfg`` / ``slo_us`` / ``seed`` / ``windows0``.
    """
    if not axes:
        raise ValueError("empty sweep: pass at least one axis")
    if resume_dir is not None and mesh is not None:
        raise ValueError("resume_dir does not compose with mesh-sharded "
                         "sweeps; run chunked-resumable sweeps unsharded")
    # A "policy" axis merges its values into ONE multi-policy
    # executable: the template grows a ``policy_set`` (jit-static — it
    # fixes the handler union compiled into the HLO) while each cell's
    # member id rides traced in ``SimParams.pol_id``.  This must happen
    # before ``sweepable_axes`` so member-declared axes (e.g.
    # ``shfl_bound``) validate against the whole set.
    if "policy" in axes:
        if not axes["policy"]:
            raise ValueError("policy axis needs at least one name")
        pset = tuple(dict.fromkeys(
            tuple(cfg.policy_set) + tuple(axes["policy"])))
        cfg = dataclasses.replace(cfg, policy_set=pset, policy=pset[0])
    allowed = sweepable_axes(cfg)
    for name in axes:
        if name not in allowed:
            raise ValueError(f"unknown sweep axis {name!r}; "
                             f"sweepable: {allowed}")
    # Sweeping a statically-gated feature must switch its gate on in the
    # template config (the gate is part of the canonical jit key).
    for gate in _GATE_AXES:
        if gate in axes and max(axes[gate]) > 0.0:
            cfg = dataclasses.replace(cfg, **{gate: max(axes[gate])})
    if not cfg.wl and any(a in axes for a in _WL_AXES):
        cfg = dataclasses.replace(cfg, wl=True)
    # Sweeping n_keys flips the key-shard gate on (the on/off bit is
    # part of the canonical jit key); the per-cell counts then ride
    # traced.  The other key axes only make sense with the gate on.
    if "n_keys" in axes:
        if any(int(v) < 1 for v in axes["n_keys"]):
            raise ValueError("n_keys axis values must be >= 1")
        if not _ks_on(cfg):
            cfg = dataclasses.replace(
                cfg, n_keys=int(max(int(v) for v in axes["n_keys"])))
    if not _ks_on(cfg) and any(a in axes for a in _KS_AXES):
        bad = [a for a in _KS_AXES if a in axes]
        raise ValueError(
            f"sweep axes {bad} need the key-shard gate on: set "
            f"SimConfig.n_keys > 0 (or include an n_keys axis)")
    if "n_locks" in axes:
        if any(not 1 <= int(v) <= cfg.n_locks for v in axes["n_locks"]):
            raise ValueError(
                f"n_locks axis values must lie in [1, cfg.n_locks="
                f"{cfg.n_locks}] (the padded lock-vector size)")
    # Sweeping a power column with any nonzero watts must flip the
    # static energy gate on: the swept values ride in the per-cell
    # tables; the template only needs a non-empty power field so _canon
    # keeps the integration ops ((0.0,) pads to the all-zero default —
    # bit-identical tables for cells that don't sweep it).
    if not _energy_on(cfg) and any(
            a in axes and any(any(float(x) != 0.0 for x in v)
                              for v in axes[a])
            for a in _energy.POWER_COLUMNS):
        cfg = dataclasses.replace(cfg, p_idle=(0.0,))
    names = list(axes)
    vals = [list(axes[k]) for k in names]
    if product:
        idx = list(itertools.product(*(range(len(v)) for v in vals)))
    else:
        if len({len(v) for v in vals}) > 1:
            raise ValueError("product=False requires equal-length axes")
        idx = [(i,) * len(vals) for i in range(len(vals[0]))] \
            if vals else [()]
    cells = [{k: vals[j][ii[j]] for j, k in enumerate(names)} for ii in idx]
    if not cells:
        raise ValueError("empty sweep")
    if "n_cores" in axes and max(axes["n_cores"]) > cfg.n_cores:
        raise ValueError("n_cores axis exceeds the padded cfg.n_cores")
    if any(a in axes for a in _KS_AXES):
        for cell in cells:
            nk = int(cell.get("n_keys", cfg.n_keys))
            nl = int(cell.get("n_locks", cfg.n_locks))
            if nk < nl:
                raise ValueError(
                    f"sweep cell pairs n_keys={nk} with n_locks={nl}: "
                    f"every lock needs at least one key")

    # Per-cell tables (rebuilt only when a program/column axis is swept).
    tbl_axes = table_axes()
    table_keys = [k for k in names if k in tbl_axes]
    if table_keys:
        tbs = [build_tables(_cell_tables_cfg(cfg, cell, table_keys))
               for cell in cells]
        tb = jax.tree.map(lambda *xs: jnp.stack(xs), *tbs)
    else:
        tb1 = build_tables(cfg)
        tb = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (len(cells),) + x.shape), tb1)

    pms = [_cell_params(cfg, cell, slo_us, seed) for cell in cells]
    pm = jax.tree.map(lambda *xs: jnp.stack(xs), *pms)

    base_w = _default_windows(cfg) if windows0 is None else \
        np.asarray(windows0, np.float32)
    w0 = np.stack([
        np.full(cfg.n_cores, _ticks(cell["window0_us"]), np.float32)
        if "window0_us" in cell else base_w for cell in cells])

    n_cells, pad = len(cells), 0
    if mesh is not None:
        from repro.dist.sharding import build_sweep_rules
        from jax.sharding import NamedSharding
        rules = build_sweep_rules(mesh, data_axis=data_axis)
        n_shards = rules.num_shards("cells")
        pad = (-n_cells) % n_shards
        if pad:  # equal row splits: duplicate the last cell, trim below
            rep = partial(jnp.repeat, repeats=pad, axis=0)
            tb = jax.tree.map(lambda x: jnp.concatenate([x, rep(x[-1:])]),
                              tb)
            pm = jax.tree.map(lambda x: jnp.concatenate([x, rep(x[-1:])]),
                              pm)
            w0 = np.concatenate([w0, np.repeat(w0[-1:], pad, axis=0)])
        ns = NamedSharding(mesh, rules.spec(("cells",), (n_cells + pad,)))
        tb, pm = jax.device_put((tb, pm), ns)
        w0 = jax.device_put(w0, ns)

    if resume_dir is not None:
        st = _sweep_resumable(_canon(cfg), tb, pm, w0, resume_dir,
                              resume_chunk)
    else:
        compiled, rec = _batch_executable(_canon(cfg), tb, pm, w0)
        _log_sweep(rec)
        st = compiled(tb, pm, w0)
    if pad:
        st = jax.tree.map(lambda x: x[:n_cells], st)
    grid = {k: np.asarray([cell[k] for cell in cells], dtype=object)
            if k in tbl_axes else np.asarray([cell[k] for cell in cells])
            for k in names}
    return st, grid


def sweep_slo(cfg: SimConfig, slo_us_values, seed=0) -> SimState:
    """Paper Figure 8b in one call (thin wrapper over :func:`sweep`)."""
    st, _ = sweep(cfg, {"slo_us": list(np.asarray(slo_us_values, float))},
                  seed=seed)
    return st


def sweep_summaries(cfg: SimConfig, st: SimState, grid: dict,
                    warmup: int = 32, slo_us=None) -> list:
    """Host-side per-cell summaries of a sweep result (one np transfer).
    ``slo_us`` (or a swept ``slo_us`` axis) adds the goodput metrics —
    see :func:`summarize`."""
    st_np = jax.tree.map(np.asarray, st)
    n_cells = len(next(iter(grid.values()))) if grid else \
        st_np.events.shape[0]
    out = []
    for i in range(n_cells):
        cell_st = jax.tree.map(lambda x: x[i], st_np)
        n_act = int(grid["n_cores"][i]) if "n_cores" in grid else None
        cell_slo = float(grid["slo_us"][i]) if "slo_us" in grid else slo_us
        s = summarize(cfg, cell_st, warmup, n_active=n_act,
                      slo_us=cell_slo)
        s.update({k: grid[k][i] for k in grid})
        out.append(s)
    return out


# --------------------------------------------------------------------------
# Host-side summaries
# --------------------------------------------------------------------------

def _ring_values(buf: np.ndarray, cnt: int, warmup: int = 32) -> np.ndarray:
    """A core's recorded latency samples minus the first ``warmup``.

    When ``cnt <= warmup`` the result is EMPTY — every sample is warmup
    (the old ``min(warmup, cnt - 1)`` slice kept exactly one contaminated
    sample).  When the ring wrapped (``cnt > cap``) it holds the most
    recent ``cap`` samples in ring order: unroll oldest-first and trim
    the warmup samples still present, i.e. the first
    ``warmup - (cnt - cap)`` when the wrap hasn't yet evicted them all.
    Order is oldest-to-newest either way (percentiles don't care; tests
    do)."""
    cap = buf.shape[0]
    if cnt <= cap:
        return buf[min(warmup, cnt):cnt]
    pos = cnt % cap
    vals = np.concatenate([buf[pos:], buf[:pos]])
    return vals[max(0, warmup - (cnt - cap)):]


def hist_tail(cfg: SimConfig, ep_hist, cs_hist, slo_us=None,
              slo_scale=None, prefix: str = "hist_") -> dict:
    """Tail metrics from per-core streaming histograms (``cfg.hist``).

    ``ep_hist`` / ``cs_hist`` are ``[n, B]`` u32 count arrays (already
    sliced to the active cores); merging across cores is a plain sum —
    see repro.core.stats.  Returns p50/p99/p999 epoch and p99 CS
    quantiles per core class in microseconds (each within the documented
    ``sqrt(g) - 1`` relative-error bound of exact), plus the
    histogram-side SLO-good fraction when ``slo_us`` is given."""
    n = ep_hist.shape[0]
    big = np.asarray(cfg.big[:n], bool)
    lo_t, hi_t = cfg.hist_lo_us * US, cfg.hist_hi_us * US
    out = {}
    for name, mask in (("all", np.ones_like(big)), ("big", big),
                       ("little", ~big)):
        he = stats.merge(ep_hist[mask]) if mask.any() else \
            np.zeros(ep_hist.shape[1], np.uint64)
        hc = stats.merge(cs_hist[mask]) if mask.any() else \
            np.zeros(cs_hist.shape[1], np.uint64)
        for q, tag in ((50, "p50"), (99, "p99"), (99.9, "p999")):
            out[f"ep_{tag}_{prefix}{name}_us"] = \
                stats.quantile(he, q, lo_t, hi_t) / US
        out[f"cs_p99_{prefix}{name}_us"] = \
            stats.quantile(hc, 99, lo_t, hi_t) / US
    out[f"{prefix}rel_err_bound"] = stats.rel_err_bound(
        lo_t, hi_t, ep_hist.shape[1])
    if slo_us is not None:
        scl = np.ones(n) if slo_scale is None else np.asarray(slo_scale)
        good = tot = 0.0
        for c in range(n):
            good += stats.good_count(ep_hist[c], slo_us * scl[c] * US,
                                     lo_t, hi_t)
            tot += float(np.asarray(ep_hist[c], np.uint64).sum())
        out[f"slo_good_frac_{prefix.rstrip('_')}"] = \
            good / tot if tot else float("nan")
    return out


def fleet_tail(cfg: SimConfig, st: SimState, slo_us=None) -> dict:
    """Fleet-wide tail metrics from a (possibly batched / sharded)
    sweep state: merge the streaming histograms across EVERY leading
    axis — sweep cells, shards, devices — and all cores with one
    sum-reduction, then reconstruct quantiles host-side.  The only host
    transfer is the two ``[B]`` count vectors, never raw samples.

    The device-side partial sum is u32 (JAX default-x64-off); each
    merged bucket must stay < 2^32 counts, which a 5M-event-per-cell cap
    comfortably guarantees up to ~800 cells per bucket-dominating
    workload — the host-side final merge is u64 either way."""
    if not cfg.hist:
        raise ValueError("fleet_tail needs a cfg with hist=True")
    merged = jax.jit(
        lambda e, c: (jnp.sum(e.reshape(-1, e.shape[-1]), axis=0),
                      jnp.sum(c.reshape(-1, c.shape[-1]), axis=0)))(
        st.ep_hist, st.cs_hist)
    eph, csh = (np.asarray(h, np.uint64)[None] for h in merged)
    # Class masks don't survive the cross-core merge — fleet view only.
    cfg1 = dataclasses.replace(cfg, n_cores=1, big=(0,),
                               speed_cs=(1.0,), speed_nc=(1.0,))
    out = {k: v for k, v in hist_tail(cfg1, eph, csh, slo_us).items()
           if "_big_" not in k and "_little_" not in k}
    return out


def summarize(cfg: SimConfig, st: SimState, warmup: int = 32,
              n_active: int = None, slo_us: float = None) -> dict:
    """Throughput + tail latency per core class (all values in us).
    ``n_active`` slices per-core outputs for padded sweep cells.
    ``slo_us`` adds goodput: the fraction of sampled epochs within the
    per-core SLO (``slo_us * slo_scale[c]``) and the epochs/s that
    fraction represents — the chaos figures' useful-work metric."""
    n = cfg.n_cores if n_active is None else int(n_active)
    big = np.asarray(cfg.big[:n], bool)
    ep_lat = np.asarray(st.ep_lat)[:n]
    ep_cnt = np.asarray(st.ep_cnt)[:n]
    cs_lat = np.asarray(st.cs_lat)[:n]
    cs_cnt = np.asarray(st.cs_cnt)[:n]
    t_end = float(np.asarray(st.t)) / US
    sim_s = max(t_end, 1e-9) / 1e6
    cap = ep_lat.shape[1]
    wrapped = bool((ep_cnt > cap).any() or (cs_cnt > cap).any())

    # One O(n*cap) collection pass, shared by the percentile AND goodput
    # paths below — the two can never disagree on the sample set.
    ep_vals = [_ring_values(ep_lat[c], int(ep_cnt[c]), warmup)
               for c in range(n)]
    cs_vals = [_ring_values(cs_lat[c], int(cs_cnt[c]), warmup)
               for c in range(n)]

    def collect(vals, mask):
        sel = [vals[c] for c in range(n) if mask[c]]
        v = np.concatenate(sel) if sel else np.zeros(0)
        return v / US  # -> microseconds

    out = {
        "sim_time_us": t_end,
        "events": int(np.asarray(st.events)),
        "throughput_cs_per_s": float(cs_cnt.sum()) / sim_s,
        "throughput_epochs_per_s": float(ep_cnt.sum()) / sim_s,
        "cs_per_core": cs_cnt.tolist(),
        "epochs_per_core": ep_cnt.tolist(),
    }
    for name, mask in (("all", np.ones_like(big)), ("big", big),
                       ("little", ~big)):
        ep = collect(ep_vals, mask)
        cs = collect(cs_vals, mask)
        out[f"ep_p99_{name}_us"] = stats.percentile(ep, 99)
        out[f"ep_p50_{name}_us"] = stats.percentile(ep, 50)
        out[f"cs_p99_{name}_us"] = stats.percentile(cs, 99)
    if wrapped:
        # A ring overwrote history: the exact percentiles above only see
        # the most recent `epcap` samples (recency-biased).  The flag is
        # emitted ONLY when it fires, so un-wrapped (e.g. golden-digest)
        # summaries are byte-identical to pre-histogram builds.
        out["tail_truncated"] = True
    if cfg.hist:
        # Streaming-histogram tail: full-history quantiles at bounded
        # relative error, any run length (docs/simulator.md §Streaming
        # metrics).  Keyed ep_*_hist_* alongside the ring-exact keys;
        # when the ring wrapped, the histogram values REPLACE the
        # primary ep/cs percentile keys — bounded error beats silently
        # truncated history.  NOTE the histogram warmup is the traced
        # ``cfg.hist_warmup`` (recorded on device), not this function's
        # ``warmup`` argument.
        eph = np.asarray(st.ep_hist, np.uint64)[:n]
        csh = np.asarray(st.cs_hist, np.uint64)[:n]
        out.update(hist_tail(cfg, eph, csh))
        if wrapped:
            for name in ("all", "big", "little"):
                out[f"ep_p99_{name}_us"] = out[f"ep_p99_hist_{name}_us"]
                out[f"ep_p50_{name}_us"] = out[f"ep_p50_hist_{name}_us"]
                out[f"cs_p99_{name}_us"] = out[f"cs_p99_hist_{name}_us"]
    out["final_window_us"] = (np.asarray(st.window)[:n] / US).tolist()
    # Energy (repro.core.energy): the accumulator is in watt-ticks and
    # 1 tick = 10 ns, so 1 watt-tick = 10 nJ.  The derived efficiency
    # metrics only appear when some energy was actually modeled.
    e_j = np.asarray(st.energy)[:n].astype(float) * 1e-8
    out["energy_per_core_j"] = e_j.tolist()
    out["energy_j"] = float(e_j.sum())
    if out["energy_j"] > 0.0:
        out["power_w"] = out["energy_j"] / sim_s
        out["tput_per_watt"] = (out["throughput_cs_per_s"]
                                / out["power_w"])
        p50 = out["ep_p50_all_us"]
        # EDP = energy x delay (J*s); delay = the median epoch latency.
        out["edp"] = out["energy_j"] * p50 * 1e-6 if np.isfinite(p50) \
            else float("nan")
    if slo_us is not None:
        # The registered column is the one source of truth for the
        # per-core SLO multiplier (encoding + neutral padding).
        scl = colreg.COLUMNS["slo_scale"].np_values(cfg, n)
        good = tot = 0
        for c in range(n):
            v = ep_vals[c]  # the same samples the percentiles used
            good += int(np.sum(v / US <= slo_us * scl[c]))
            tot += v.size
        frac = good / tot if tot else 0.0
        if cfg.hist:
            hg = hist_tail(cfg, eph, csh, slo_us=slo_us, slo_scale=scl)
            out["slo_good_frac_hist"] = hg["slo_good_frac_hist"]
            if wrapped:
                # Ring history truncated -> the ring fraction only sees
                # the most recent epcap epochs; report the full-history
                # histogram fraction as the primary goodput.
                frac = out["slo_good_frac_hist"]
        out["slo_good_frac"] = frac
        out["goodput_eps"] = out["throughput_epochs_per_s"] * frac
    return out
