"""Discrete-event AMP lock simulator — the paper's experiments as a JAX module.

This container has one CPU core, so the paper's wall-clock experiments on
asymmetric silicon cannot be re-run directly.  Instead we reproduce them on a
deterministic discrete-event simulation of an AMP: ``N`` cores with per-core
speed factors run (non-critical section → acquire → critical section →
release) loops against ``L`` shared locks under a pluggable lock policy.

The simulator is a single ``jax.lax.while_loop`` over integer event time
(ticks of 10 ns), so an SLO sweep (paper Figure 8b) is one ``jax.vmap`` and a
whole figure is one jitted call.  All paper baselines are modeled:

* ``fifo``    — MCS-equivalent strict FIFO handoff (Implication 1 baseline).
* ``tas``     — test-and-set with an *asymmetric success rate*: the winner
                among spinners at release is drawn with weight ``w_big`` for
                big cores (w_big>1 = big-core-affinity, <1 = little-core-
                affinity; paper Figure 3b/3c).
* ``prop``    — static proportional policy (ShflLock-PB analogue, Figure 5):
                1 little-core grant after every ``prop_n`` big-core grants.
* ``libasl``  — the paper: big cores enqueue immediately; little cores stand
                by for an AIMD-controlled reorder window (Algorithms 1-3).

Event model (one pending event per core):
  NONCRIT end  → acquire attempt (policy-specific)
  STANDBY end  → reorder window expired → enqueue FIFO
  HOLDER end   → release: record latencies, advance epoch, pick next holder
QUEUED / SPIN cores carry t_ready=INF and are woken by the releaser.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# Phases
NONCRIT, STANDBY, QUEUED, HOLDER, SPIN = 0, 1, 2, 3, 4
INF = jnp.int32(1 << 30)

POLICIES = {"fifo": 0, "tas": 1, "prop": 2, "libasl": 3}

# 1 tick = 10 ns
US = 100  # ticks per microsecond


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Static simulator configuration (hashable -> usable as jit static arg)."""

    policy: str = "fifo"
    n_cores: int = 8
    big: tuple = (1, 1, 1, 1, 0, 0, 0, 0)          # 4 big + 4 little (M1)
    speed_cs: tuple = (1.0,) * 4 + (3.75,) * 4     # CS slowdown (Sysbench gap)
    speed_nc: tuple = (1.0,) * 4 + (1.8,) * 4      # non-CS slowdown (NOP gap)
    # Epoch program: S segments of (noncrit_us, cs_us, lock_id)
    seg_noncrit_us: tuple = (1.0,)
    seg_cs_us: tuple = (3.0,)
    seg_lock: tuple = (0,)
    inter_epoch_us: float = 5.0
    n_locks: int = 1
    pct: float = 99.0
    w_big: float = 1.0            # TAS affinity weight
    prop_n: int = 10              # proportional policy ratio
    default_window_us: float = 10.0
    max_window_us: float = 100_000.0   # 100 ms upper bound (starvation-free)
    sim_time_us: float = 100_000.0
    epcap: int = 8192             # latency ring size
    max_events: int = 5_000_000
    # Bench-3: heterogeneous epochs — with prob p the next epoch's
    # non-critical work is scale x longer (long request mixed with short).
    long_epoch_prob: float = 0.0
    long_epoch_scale: float = 100.0
    # Bench-6: blocking locks — FIFO handoff to a parked waiter pays a
    # wakeup latency; a standby grabbing a free lock (spinning) does not.
    wakeup_us: float = 0.0

    @property
    def policy_id(self) -> int:
        return POLICIES[self.policy]


class SimState(NamedTuple):
    t: jnp.ndarray
    key: jnp.ndarray
    phase: jnp.ndarray        # i32[N]
    t_ready: jnp.ndarray      # i32[N]
    seg: jnp.ndarray          # i32[N]
    epoch_start: jnp.ndarray  # i32[N]
    attempt_t: jnp.ndarray    # i32[N]
    window: jnp.ndarray       # f32[N] (ticks)
    unit: jnp.ndarray         # f32[N]
    scale: jnp.ndarray        # f32[N] current epoch noncrit scale (Bench-3)
    q: jnp.ndarray            # i32[L,2,N] ring buffers (0=main/big, 1=little)
    q_head: jnp.ndarray       # i32[L,2]
    q_tail: jnp.ndarray       # i32[L,2]
    holder: jnp.ndarray       # i32[L]
    prop_ctr: jnp.ndarray     # i32[L]
    ep_lat: jnp.ndarray       # f32[N,EPCAP] epoch latencies (ticks)
    ep_cnt: jnp.ndarray       # i32[N]
    cs_lat: jnp.ndarray       # f32[N,EPCAP] acquire->release latencies
    cs_cnt: jnp.ndarray       # i32[N]
    events: jnp.ndarray       # i32


def _ticks(us: float) -> int:
    return int(round(us * US))


def init_state(cfg: SimConfig, seed: int = 0, windows0=None) -> SimState:
    n, l, cap = cfg.n_cores, cfg.n_locks, cfg.epcap
    nc0 = jnp.asarray(
        [_ticks(cfg.seg_noncrit_us[0] * cfg.speed_nc[c]) for c in range(n)],
        jnp.int32)
    # Stagger initial arrivals slightly so ties don't all collapse to core 0.
    stagger = jnp.arange(n, dtype=jnp.int32)
    return SimState(
        t=jnp.int32(0),
        key=jax.random.PRNGKey(seed),
        phase=jnp.zeros(n, jnp.int32),
        t_ready=nc0 + stagger,
        seg=jnp.zeros(n, jnp.int32),
        epoch_start=jnp.zeros(n, jnp.int32),
        attempt_t=jnp.zeros(n, jnp.int32),
        window=(jnp.asarray(windows0, jnp.float32) if windows0 is not None
                else jnp.full(n, _ticks(cfg.default_window_us), jnp.float32)),
        unit=jnp.full(n, _ticks(cfg.default_window_us) * (100.0 - cfg.pct) / 100.0,
                      jnp.float32),
        q=jnp.full((l, 2, n), -1, jnp.int32),
        q_head=jnp.zeros((l, 2), jnp.int32),
        q_tail=jnp.zeros((l, 2), jnp.int32),
        holder=jnp.full(l, -1, jnp.int32),
        prop_ctr=jnp.zeros(l, jnp.int32),
        scale=jnp.ones(n, jnp.float32),
        ep_lat=jnp.zeros((n, cap), jnp.float32),
        ep_cnt=jnp.zeros(n, jnp.int32),
        cs_lat=jnp.zeros((n, cap), jnp.float32),
        cs_cnt=jnp.zeros(n, jnp.int32),
        events=jnp.int32(0),
    )


# --------------------------------------------------------------------------
# Static per-config arrays
# --------------------------------------------------------------------------

def _tables(cfg: SimConfig):
    n = cfg.n_cores
    s = len(cfg.seg_cs_us)
    big = jnp.asarray(cfg.big[:n], jnp.int32)
    cs_dur = jnp.asarray(
        [[_ticks(cfg.seg_cs_us[j] * cfg.speed_cs[c]) for j in range(s)]
         for c in range(n)], jnp.int32)          # [N,S]
    nc_dur = jnp.asarray(
        [[_ticks(cfg.seg_noncrit_us[j] * cfg.speed_nc[c]) for j in range(s)]
         for c in range(n)], jnp.int32)          # [N,S]
    inter = jnp.asarray(
        [_ticks(cfg.inter_epoch_us * cfg.speed_nc[c]) for c in range(n)],
        jnp.int32)                                # [N]
    seg_lock = jnp.asarray(cfg.seg_lock, jnp.int32)  # [S]
    return big, cs_dur, nc_dur, inter, seg_lock


# --------------------------------------------------------------------------
# Queue helpers (ring buffers). All conditional: ops are no-ops when !cond.
# --------------------------------------------------------------------------

def _enq(st: SimState, cond, l, b, c) -> SimState:
    n = st.q.shape[-1]
    pos = st.q_tail[l, b] % n
    val = jnp.where(cond, c, st.q[l, b, pos])
    q = st.q.at[l, b, pos].set(val)
    q_tail = st.q_tail.at[l, b].add(jnp.where(cond, 1, 0))
    return st._replace(q=q, q_tail=q_tail)


def _deq(st: SimState, cond, l, b):
    """Returns (st, core) — core = -1 when !cond or empty."""
    n = st.q.shape[-1]
    nonempty = st.q_tail[l, b] > st.q_head[l, b]
    do = jnp.logical_and(cond, nonempty)
    pos = st.q_head[l, b] % n
    c = jnp.where(do, st.q[l, b, pos], -1)
    q_head = st.q_head.at[l, b].add(jnp.where(do, 1, 0))
    return st._replace(q_head=q_head), c


def _qlen(st: SimState, l, b):
    return st.q_tail[l, b] - st.q_head[l, b]


# --------------------------------------------------------------------------
# Event handlers
# --------------------------------------------------------------------------

def _grant(st: SimState, cfg: SimConfig, cond, c, t, wakeup=False) -> SimState:
    """Make core c (if cond) the holder of its lock; schedule its release.
    ``wakeup=True`` models a blocking lock's parked-waiter handoff latency
    (Bench-6): only queue-pop handoffs pay it, spinners/standbys do not."""
    _, cs_dur, _, _, seg_lock = _tables(cfg)
    c_safe = jnp.maximum(c, 0)
    l = seg_lock[st.seg[c_safe]]
    dur = cs_dur[c_safe, st.seg[c_safe]]
    if wakeup and cfg.wakeup_us:
        dur = dur + _ticks(cfg.wakeup_us)
    holder = st.holder.at[l].set(jnp.where(cond, c_safe, st.holder[l]))
    phase = st.phase.at[c_safe].set(
        jnp.where(cond, HOLDER, st.phase[c_safe]))
    t_ready = st.t_ready.at[c_safe].set(
        jnp.where(cond, t + dur, st.t_ready[c_safe]))
    return st._replace(holder=holder, phase=phase, t_ready=t_ready)


def _handle_acquire(st: SimState, cfg: SimConfig, c, t) -> SimState:
    big, _, _, _, seg_lock = _tables(cfg)
    l = seg_lock[st.seg[c]]
    st = st._replace(attempt_t=st.attempt_t.at[c].set(t))
    is_big = big[c] == 1
    free = st.holder[l] == -1

    if cfg.policy == "tas":
        # Free -> grab; else spin (woken at release by weighted draw).
        st = _grant(st, cfg, free, c, t)
        st = st._replace(
            phase=st.phase.at[c].set(jnp.where(free, st.phase[c], SPIN)),
            t_ready=st.t_ready.at[c].set(jnp.where(free, st.t_ready[c], INF)))
        return st

    if cfg.policy == "prop":
        q_empty = jnp.logical_and(_qlen(st, l, 0) == 0, _qlen(st, l, 1) == 0)
        grab = jnp.logical_and(free, q_empty)
        st = _grant(st, cfg, grab, c, t)
        b = jnp.where(is_big, 0, 1)
        st = _enq(st, ~grab, l, b, c)
        st = st._replace(
            phase=st.phase.at[c].set(jnp.where(grab, st.phase[c], QUEUED)),
            t_ready=st.t_ready.at[c].set(jnp.where(grab, st.t_ready[c], INF)))
        return st

    if cfg.policy == "libasl":
        q_empty = _qlen(st, l, 0) == 0
        grab = jnp.logical_and(free, q_empty)
        # Big cores: lock_immediately == FIFO enqueue. Little: standby.
        enq = jnp.logical_and(~grab, is_big)
        standby = jnp.logical_and(~grab, ~is_big)
        st = _grant(st, cfg, grab, c, t)
        st = _enq(st, enq, l, 0, c)
        win = jnp.minimum(st.window[c], _ticks(cfg.max_window_us)).astype(jnp.int32)
        new_phase = jnp.where(grab, st.phase[c],
                              jnp.where(is_big, QUEUED, STANDBY))
        new_ready = jnp.where(grab, st.t_ready[c],
                              jnp.where(is_big, INF, t + jnp.maximum(win, 0)))
        st = st._replace(
            phase=st.phase.at[c].set(new_phase),
            t_ready=st.t_ready.at[c].set(new_ready))
        return st

    # fifo (MCS)
    q_empty = _qlen(st, l, 0) == 0
    grab = jnp.logical_and(free, q_empty)
    st = _grant(st, cfg, grab, c, t)
    st = _enq(st, ~grab, l, 0, c)
    st = st._replace(
        phase=st.phase.at[c].set(jnp.where(grab, st.phase[c], QUEUED)),
        t_ready=st.t_ready.at[c].set(jnp.where(grab, st.t_ready[c], INF)))
    return st


def _handle_standby_expiry(st: SimState, cfg: SimConfig, c, t) -> SimState:
    """LibASL little core: reorder window expired -> enqueue FIFO (Alg.1 l.16)."""
    _, _, _, _, seg_lock = _tables(cfg)
    l = seg_lock[st.seg[c]]
    free = jnp.logical_and(st.holder[l] == -1, _qlen(st, l, 0) == 0)
    st = _grant(st, cfg, free, c, t)
    st = _enq(st, ~free, l, 0, c)
    st = st._replace(
        phase=st.phase.at[c].set(jnp.where(free, st.phase[c], QUEUED)),
        t_ready=st.t_ready.at[c].set(jnp.where(free, st.t_ready[c], INF)))
    return st


def _record(buf, cnt, c, value, cond):
    cap = buf.shape[1]
    pos = cnt[c] % cap
    val = jnp.where(cond, value, buf[c, pos])
    return buf.at[c, pos].set(val), cnt.at[c].add(jnp.where(cond, 1, 0))


def _pick_next(st: SimState, cfg: SimConfig, l, t, slo):
    """Select & grant the next holder of lock l after a release."""
    big, cs_dur, _, _, seg_lock = _tables(cfg)
    n = cfg.n_cores

    if cfg.policy == "tas":
        spinning = jnp.logical_and(st.phase == SPIN, seg_lock[st.seg] == l)
        any_spin = jnp.any(spinning)
        key, sub = jax.random.split(st.key)
        w = jnp.where(big == 1, cfg.w_big, 1.0)
        logits = jnp.where(spinning, jnp.log(w), -jnp.inf)
        winner = jax.random.categorical(sub, logits)
        st = st._replace(key=key)
        st = _grant(st, cfg, any_spin, winner, t)
        holder = st.holder.at[l].set(
            jnp.where(any_spin, st.holder[l], -1))
        return st._replace(holder=holder)

    if cfg.policy == "prop":
        nb, nl = _qlen(st, l, 0), _qlen(st, l, 1)
        take_big = jnp.logical_and(
            nb > 0, jnp.logical_or(st.prop_ctr[l] < cfg.prop_n, nl == 0))
        take_little = jnp.logical_and(~take_big, nl > 0)
        st, cb = _deq(st, take_big, l, 0)
        st, cl = _deq(st, take_little, l, 1)
        nxt = jnp.where(take_big, cb, cl)
        has = jnp.logical_or(take_big, take_little)
        ctr = jnp.where(take_big, st.prop_ctr[l] + 1,
                        jnp.where(take_little, 0, st.prop_ctr[l]))
        st = st._replace(prop_ctr=st.prop_ctr.at[l].set(ctr))
        st = _grant(st, cfg, has, nxt, t, wakeup=True)
        holder = st.holder.at[l].set(jnp.where(has, st.holder[l], -1))
        return st._replace(holder=holder)

    # fifo & libasl: FIFO queue first.
    nonempty = _qlen(st, l, 0) > 0
    st, cq = _deq(st, nonempty, l, 0)
    st = _grant(st, cfg, nonempty, cq, t, wakeup=True)

    if cfg.policy == "libasl":
        # Queue empty -> a standby competitor may grab the free lock
        # (Algorithm 1: "when the waiting queue is empty").
        standby = jnp.logical_and(st.phase == STANDBY, seg_lock[st.seg] == l)
        any_standby = jnp.logical_and(~nonempty, jnp.any(standby))
        key, sub = jax.random.split(st.key)
        logits = jnp.where(standby, 0.0, -jnp.inf)
        pick = jax.random.categorical(sub, logits)
        st = st._replace(key=key)
        st = _grant(st, cfg, any_standby, pick, t)
        has = jnp.logical_or(nonempty, any_standby)
        holder = st.holder.at[l].set(jnp.where(has, st.holder[l], -1))
        return st._replace(holder=holder)

    holder = st.holder.at[l].set(jnp.where(nonempty, st.holder[l], -1))
    return st._replace(holder=holder)


def _handle_release(st: SimState, cfg: SimConfig, c, t, slo) -> SimState:
    big, cs_dur, nc_dur, inter, seg_lock = _tables(cfg)
    s = st.seg[c]
    l = seg_lock[s]
    n_seg = len(cfg.seg_cs_us)

    # acquire->release latency (paper Figure 1 metric)
    cs_lat, cs_cnt = _record(st.cs_lat, st.cs_cnt, c,
                             (t - st.attempt_t[c]).astype(jnp.float32), True)
    st = st._replace(cs_lat=cs_lat, cs_cnt=cs_cnt)

    last = s == n_seg - 1
    # Epoch end: record latency, AIMD-update the window (little cores only).
    ep_latency = (t - st.epoch_start[c]).astype(jnp.float32)
    ep_lat, ep_cnt = _record(st.ep_lat, st.ep_cnt, c, ep_latency, last)
    st = st._replace(ep_lat=ep_lat, ep_cnt=ep_cnt)

    if cfg.policy == "libasl":
        adjust = jnp.logical_and(last, big[c] == 0)
        violated = ep_latency > slo
        w = jnp.where(violated, st.window[c] * 0.5, st.window[c])
        u = jnp.where(violated, w * (100.0 - cfg.pct) / 100.0, st.unit[c])
        w = jnp.clip(w + u, 0.0, _ticks(cfg.max_window_us))
        st = st._replace(
            window=st.window.at[c].set(jnp.where(adjust, w, st.window[c])),
            unit=st.unit.at[c].set(jnp.where(adjust, u, st.unit[c])))

    # Bench-3: sample the next epoch's noncrit scale (heterogeneous mix).
    scale_c = st.scale[c]
    if cfg.long_epoch_prob > 0.0:
        key, sub = jax.random.split(st.key)
        u = jax.random.uniform(sub)
        new_scale = jnp.where(u < cfg.long_epoch_prob,
                              jnp.float32(cfg.long_epoch_scale),
                              jnp.float32(1.0))
        st = st._replace(key=key,
                         scale=st.scale.at[c].set(
                             jnp.where(last, new_scale, scale_c)))
        scale_c = jnp.where(last, new_scale, scale_c)

    def _sc(d):
        return (d.astype(jnp.float32) * scale_c).astype(jnp.int32)

    # Advance the program: next segment, or inter-epoch gap then segment 0.
    s_next = jnp.where(last, 0, s + 1)
    ep_start_next = jnp.where(last, t + _sc(inter[c]), st.epoch_start[c])
    ready = jnp.where(last,
                      t + _sc(inter[c]) + _sc(nc_dur[c, 0]),
                      t + _sc(nc_dur[c, jnp.minimum(s + 1, n_seg - 1)]))
    st = st._replace(
        seg=st.seg.at[c].set(s_next),
        epoch_start=st.epoch_start.at[c].set(ep_start_next),
        phase=st.phase.at[c].set(NONCRIT),
        t_ready=st.t_ready.at[c].set(ready))

    # Hand the lock over.
    st = st._replace(holder=st.holder.at[l].set(-1))
    return _pick_next(st, cfg, l, t, slo)


# --------------------------------------------------------------------------
# Main loop
# --------------------------------------------------------------------------

def _step(cfg: SimConfig, slo, st: SimState) -> SimState:
    c = jnp.argmin(st.t_ready).astype(jnp.int32)
    t = st.t_ready[c]
    st = st._replace(t=t, events=st.events + 1)

    def acq(s):
        return _handle_acquire(s, cfg, c, t)

    def standby(s):
        return _handle_standby_expiry(s, cfg, c, t)

    def rel(s):
        return _handle_release(s, cfg, c, t, slo)

    def noop(s):
        return s._replace(t_ready=s.t_ready.at[c].set(INF))

    return jax.lax.switch(st.phase[c], [acq, standby, noop, rel, noop], st)


@partial(jax.jit, static_argnums=(0,))
def run(cfg: SimConfig, slo_us, seed=0, windows0=None) -> SimState:
    """Run the simulation; slo_us may be a traced scalar (vmap over sweeps).
    ``windows0`` carries AIMD state across phases (Bench-2)."""
    slo = (slo_us * US).astype(jnp.float32) if hasattr(slo_us, "astype") \
        else jnp.float32(_ticks(slo_us))
    st = init_state(cfg, seed, windows0)
    horizon = jnp.int32(_ticks(cfg.sim_time_us))

    def cond(s):
        return jnp.logical_and(jnp.min(s.t_ready) < horizon,
                               s.events < cfg.max_events)

    def body(s):
        return _step(cfg, slo, s)

    return jax.lax.while_loop(cond, body, st)


def sweep_slo(cfg: SimConfig, slo_us_values, seed=0) -> SimState:
    """Paper Figure 8b in one call: vmap the whole simulation over SLOs."""
    slos = jnp.asarray(slo_us_values, jnp.float32)
    return jax.vmap(lambda s: run(cfg, s, seed))(slos)


# --------------------------------------------------------------------------
# Host-side summaries
# --------------------------------------------------------------------------

def _ring_values(buf: np.ndarray, cnt: int, warmup: int = 32) -> np.ndarray:
    cap = buf.shape[0]
    if cnt <= cap:
        vals = buf[:cnt]
        return vals[min(warmup, max(cnt - 1, 0)):]
    return buf  # ring wrapped: holds the most recent `cap` samples

def summarize(cfg: SimConfig, st: SimState, warmup: int = 32) -> dict:
    """Throughput + tail latency per core class (all values in us)."""
    big = np.asarray(cfg.big[:cfg.n_cores], bool)
    ep_lat = np.asarray(st.ep_lat)
    ep_cnt = np.asarray(st.ep_cnt)
    cs_lat = np.asarray(st.cs_lat)
    cs_cnt = np.asarray(st.cs_cnt)
    t_end = float(np.asarray(st.t)) / US
    sim_s = max(t_end, 1e-9) / 1e6

    def collect(lat, cnt, mask):
        vals = [
            _ring_values(lat[c], int(cnt[c]), warmup)
            for c in range(cfg.n_cores) if mask[c]
        ]
        v = np.concatenate(vals) if vals else np.zeros(0)
        return v / US  # -> microseconds

    out = {
        "sim_time_us": t_end,
        "events": int(np.asarray(st.events)),
        "throughput_cs_per_s": float(cs_cnt.sum()) / sim_s,
        "throughput_epochs_per_s": float(ep_cnt.sum()) / sim_s,
        "cs_per_core": cs_cnt.tolist(),
        "epochs_per_core": ep_cnt.tolist(),
    }
    for name, mask in (("all", np.ones_like(big)), ("big", big),
                       ("little", ~big)):
        ep = collect(ep_lat, ep_cnt, mask)
        cs = collect(cs_lat, cs_cnt, mask)
        out[f"ep_p99_{name}_us"] = float(np.percentile(ep, 99)) if ep.size else float("nan")
        out[f"ep_p50_{name}_us"] = float(np.percentile(ep, 50)) if ep.size else float("nan")
        out[f"cs_p99_{name}_us"] = float(np.percentile(cs, 99)) if cs.size else float("nan")
    out["final_window_us"] = (np.asarray(st.window) / US).tolist()
    return out
