"""Multi-class clients: per-class SLOs, mix ratios, core affinity.

One workload can model latency-critical + best-effort tenants side by
side (paper Fig 8c): each :class:`ClientClass` carries its share of the
arrival stream (``weight``), its own SLO and service distribution, and —
for the AMP lock simulator, where each core *is* a client — a big/little
core affinity.

Consumers:

* the serving engine: :func:`multiclass_workload` drives a
  ``ServingEngine`` with one Poisson stream split over the classes;
  ``epoch_id`` = class index, so the ASL scheduler keeps one AIMD
  reorder window per class (the paper's per-epoch-id windows).
* the lock simulator: :func:`amp_config` maps classes onto cores
  (affinity + weights) and emits the per-core SLO-scale table that rides
  traced in ``SimTables`` — one batched sweep covers all tenants.
* the trace recorder: ``traces.generate(..., classes=mix)`` stamps each
  request with its class id and per-class service draw.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import stats
from repro.workloads.generators import STREAM_CLASS, ServiceSpec, choice


@dataclasses.dataclass(frozen=True)
class ClientClass:
    """One tenant class of a mixed workload."""

    name: str
    weight: float = 1.0                  # share of the arrival stream
    slo: float = math.inf                # per-class SLO (consumer units)
    service: ServiceSpec = ServiceSpec()
    affinity: str = "any"                # "big" | "little" | "any"

    def __post_init__(self):
        if self.affinity not in ("big", "little", "any"):
            raise ValueError(f"bad affinity {self.affinity!r}")
        if self.weight <= 0:
            raise ValueError("class weight must be positive")


@dataclasses.dataclass(frozen=True)
class WorkloadMix:
    """A weighted set of client classes."""

    classes: tuple

    def __post_init__(self):
        if not self.classes:
            raise ValueError("empty mix")

    def probs(self) -> np.ndarray:
        w = np.asarray([c.weight for c in self.classes], np.float64)
        return w / w.sum()

    def class_ids(self, n: int, seed: int,
                  *, stream: int = STREAM_CLASS) -> np.ndarray:
        """Class id per request — counter-based categorical by weight
        (one sampler: generators.choice)."""
        return choice(np.arange(len(self.classes), dtype=np.int32), n,
                      seed, stream=stream,
                      weights=[c.weight for c in self.classes])

    def slos(self) -> np.ndarray:
        return np.asarray([c.slo for c in self.classes], np.float64)


def assign_cores(mix: WorkloadMix, big) -> np.ndarray:
    """Class id per core honoring affinity, then weight shares.

    ``big`` is the simulator's per-core big/little tuple.  Cores with a
    class's affinity are claimed first (big-affine classes take big cores
    etc.); "any" classes fill the remainder by weight.  Every core gets
    a class; raises if an affinity cannot be satisfied at all.
    """
    big = np.asarray(big, bool)
    n = len(big)
    out = np.full(n, -1, np.int64)
    pools = {"big": [c for c in range(n) if big[c]],
             "little": [c for c in range(n) if not big[c]]}
    # Target core counts proportional to weight (at least 1 per class).
    p = mix.probs()
    want = np.maximum(1, np.round(p * n).astype(int))
    affine = [(k, c) for k, c in enumerate(mix.classes)
              if c.affinity != "any"]
    for k, cls in affine:
        pool = pools[cls.affinity]
        if not pool:
            raise ValueError(f"class {cls.name!r} wants {cls.affinity} "
                             "cores but none are left")
        take = pool[:max(1, min(want[k], len(pool)))]
        for c in take:
            out[c] = k
            pool.remove(c)
    rest = [c for c in range(n) if out[c] < 0]
    anyk = [k for k, c in enumerate(mix.classes) if c.affinity == "any"]
    if rest and not anyk:
        anyk = list(range(len(mix.classes)))   # spill onto affine classes
    for i, c in enumerate(rest):
        # round-robin weighted: repeat class k want[k] times
        seq = [k for k in anyk for _ in range(int(want[k]))] or anyk
        out[c] = seq[i % len(seq)]
    return out


def amp_config(cfg, mix: WorkloadMix, base_slo: float):
    """Specialize a ``SimConfig`` for a multi-class tenancy.

    Maps classes to cores (:func:`assign_cores`) and installs the
    per-core ``slo_scale`` table (class SLO / ``base_slo``) — run the
    result with ``slo_us=base_slo`` and each core's effective SLO is its
    class's own.  A class that declares a non-default :class:`ServiceSpec`
    additionally installs its service *shape* into the per-core
    ``wl_service_per_core`` table (big/little tenants with different
    Get/Put mixes side by side); the shape parameters (``cv`` / ``mix``
    / ``mix_scale``) stay run-wide traced knobs.  Returns
    ``(cfg, class_of_core)``.
    """
    # Lazy import: simlock imports this package (generators) at load
    # time; by the time a SimConfig reaches amp_config it is loaded.
    from repro.core import simlock as sl
    assign = assign_cores(mix, cfg.big[:cfg.n_cores])
    scale = tuple(
        float(mix.classes[k].slo / base_slo) if
        math.isfinite(mix.classes[k].slo) else 1e9
        for k in assign)
    default = ServiceSpec()
    svc = tuple(mix.classes[k].service.dist
                if mix.classes[k].service != default else None
                for k in assign)
    if any(svc):
        cfg = sl.with_columns(cfg, wl_service=svc)
    return sl.with_columns(cfg, slo_scale=scale), assign


def multiclass_workload(engine, mix: WorkloadMix, *, rate_rps: float,
                        duration_s: float, prompt_lens, new_tokens,
                        seed: int = 0, trace=None):
    """Drive a ``ServingEngine`` with a multi-class Poisson stream.

    Every request carries its class index as ``epoch_id`` and its class
    SLO as the TTFT SLO, so the ASL scheduler maintains one AIMD window
    per class.  Returns the engine (inspect ``engine.metrics()`` /
    ``metrics_by_class``).
    """
    from repro.workloads import traces
    from repro.workloads.generators import ArrivalSpec
    if trace is None:
        trace = traces.generate(
            ArrivalSpec("poisson", rate_rps), None, duration_s, seed,
            classes=mix, cols=traces.request_columns(prompt_lens,
                                                     new_tokens))
    from repro.serving.engine import replay_workload
    return replay_workload(engine, trace)


def metrics_by_class(engine, mix: WorkloadMix,
                     warmup_frac: float = 0.1) -> dict:
    """Per-class serving metrics (TTFT tail + SLO violation rate).
    Drops a ``warmup_frac`` completion-order prefix per class, matching
    ``ServingEngine.metrics`` so the tails are comparable."""
    out = {}
    for k, cls in enumerate(mix.classes):
        reqs = [r for r in engine.done
                if r.epoch_id == k and r.first_token_t is not None]
        reqs = reqs[int(len(reqs) * warmup_frac):]
        if not reqs:
            out[cls.name] = {"n": 0}
            continue
        ttft = np.asarray([r.first_token_t - r.arrival_t for r in reqs])
        out[cls.name] = {
            "n": len(reqs),
            # repro.core.stats: nan (never a raise) on zero samples.
            "ttft_p50": stats.percentile(ttft, 50),
            "ttft_p99": stats.percentile(ttft, 99),
            "slo_violation_rate": float(np.mean(ttft > cls.slo)),
        }
    return out
