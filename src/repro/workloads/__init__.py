"""Batched stochastic workload generation for the lock + fleet simulators.

The paper's headline results come from real databases under *application
workloads*; this package is the reproduction's workload model — one layer
that every simulator consumes, so "bursty vs steady" or "mixed tenants"
is a parameter, not a fork of the simulator:

* :mod:`repro.workloads.generators` — arrival processes (closed-loop,
  open-loop Poisson, MMPP bursty on-off, diurnal ramp) and service-time
  distributions (deterministic, exponential, lognormal, bimodal Get/Put
  mix) as pure-jnp, vmap-safe samplers under a **counter-based RNG
  discipline**: every draw is a pure function of
  ``(seed, stream, *indices)``, so device-side sweeps, host-side sims and
  recorded traces all see bit-identical workloads.
* :mod:`repro.workloads.clients` — multi-class clients: per-class SLOs,
  mix ratios and big/little core affinity (paper Fig 8c tenancy).
* :mod:`repro.workloads.traces` — a small npz trace format with a
  recorder and a deterministic replayer.

Consumers: ``repro.core.simlock`` (workload axes as traced sweep
dimensions), ``repro.serving.dispatch`` / ``repro.serving.engine`` (host
arrivals + services), ``benchmarks/paper_figs.py`` (the load-latency
figure).  See docs/workloads.md.
"""

from repro.workloads.generators import (ARRIVALS, SERVICES, ArrivalSpec,
                                        ServiceSpec, arrival_times,
                                        service_times)
from repro.workloads.clients import ClientClass, WorkloadMix
from repro.workloads.traces import Trace

__all__ = [
    "ARRIVALS", "SERVICES", "ArrivalSpec", "ServiceSpec",
    "arrival_times", "service_times",
    "ClientClass", "WorkloadMix", "Trace",
]
