"""Batched stochastic workload generation for the lock + fleet simulators.

The paper's headline results come from real databases under *application
workloads*; this package is the reproduction's workload model — one layer
that every simulator consumes, so "bursty vs steady" or "mixed tenants"
is a parameter, not a fork of the simulator:

* :mod:`repro.workloads.generators` — arrival processes (closed-loop,
  open-loop Poisson, MMPP bursty on-off, diurnal ramp) and service-time
  distributions (deterministic, exponential, lognormal, bimodal Get/Put
  mix) as pure-jnp, vmap-safe samplers under a **counter-based RNG
  discipline**: every draw is a pure function of
  ``(seed, stream, *indices)``, so device-side sweeps, host-side sims and
  recorded traces all see bit-identical workloads.
* :mod:`repro.workloads.clients` — multi-class clients: per-class SLOs,
  mix ratios and big/little core affinity (paper Fig 8c tenancy).
* :mod:`repro.workloads.traces` — a small npz trace format with a
  recorder and a deterministic replayer.

Consumers: ``repro.core.simlock`` (workload axes as traced sweep
dimensions), ``repro.serving.dispatch`` / ``repro.serving.engine`` (host
arrivals + services), ``benchmarks/paper_figs.py`` (the load-latency
figure).  See docs/workloads.md.
"""

from repro.core.columns import ColumnSpec, register_column
from repro.workloads.generators import (ARRIVALS, SERVICES, ArrivalSpec,
                                        ServiceSpec, arrival_times,
                                        service_times)
from repro.workloads.clients import ClientClass, WorkloadMix
from repro.workloads.traces import Trace

# The multi-class tenancy tables ride as owned SimTables columns
# (repro.core.columns): the per-core SLO multiplier and the per-core
# service-distribution id (-1 = inherit the run-wide traced id).  Both
# keep their pre-refactor sweep-axis names via ``field``.
register_column(ColumnSpec(
    name="slo_scale", dtype="f32", default=1.0, field="slo_scale",
    owner="workloads",
    doc="per-core SLO multiplier (multi-class tenancy)"))
register_column(ColumnSpec(
    name="wl_service", dtype="i32", default=-1,
    field="wl_service_per_core", numeric=False,
    encode=lambda d: -1 if not d else SERVICES[d],
    owner="workloads",
    doc="per-core SERVICES id override (-1 = inherit wl_service)"))

__all__ = [
    "ARRIVALS", "SERVICES", "ArrivalSpec", "ServiceSpec",
    "arrival_times", "service_times",
    "ClientClass", "WorkloadMix", "Trace",
]
