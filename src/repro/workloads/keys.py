"""Key-sharded Zipf traffic — counter-pure key streams and the bucketed
key→lock index (docs/workloads.md §Key-sharded traffic).

Production datastore traffic is millions of skewed keys hammering
thousands of bucket locks.  This module supplies the two pieces the
simulator needs to model that:

* **A counter-pure Zipf key generator.**  Every key draw is a pure
  function of ``(seed, core, epoch)`` through the ``STREAM_KEY`` stream
  — the same RNG discipline as every other workload draw
  (``repro.workloads.generators``): batching, sharding, chunking and
  event interleaving cannot perturb which key an epoch touches, and the
  host can reconstruct the full key table (:func:`key_table`).
  Sampling uses the Gray et al. / YCSB ``ZipfianGenerator``
  approximation — an O(1) branchless inverse-CDF built from three
  host-precomputed constants (:func:`zipf_consts`), so the device-side
  sampler (:func:`zipf_key`) is a handful of jnp ops with the key count
  and exponent riding *traced* (sweepable inside one executable).

* **A bucketed key→lock index.**  :func:`key_to_lock` maps key ``k`` to
  bucket ``k % n_locks`` — deliberately rank-preserving: key 0 (the
  hottest) lands on lock 0, so "the hot bucket" is well-defined and the
  key-affinity policies (``ks_erew``/``ks_crew``) can pin it to a big
  core.  ``n_locks`` rides traced too, so lock-count sweeps share the
  executable.

Keys are ranked by popularity: ``P(key = k) ∝ 1/(k+1)^theta``.
``theta = 0`` is uniform, ``theta ≈ 0.99`` is the YCSB default,
``theta > 1`` concentrates mass on a handful of keys (hot-key
collapse).  ``theta`` is nudged off the harmonic pole at 1.0 host-side
(:func:`zipf_consts` returns the nudged value; use it everywhere).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.workloads.generators import (STREAM_KEY, STREAM_RW,
                                        counter_uniform, stream_key)

#: Exponents within this distance of the theta=1 pole are nudged off it
#: (the Gray/YCSB constants divide by ``1 - theta``).
_POLE_EPS = 1e-4


def zipf_consts(n_keys: int, theta: float):
    """Host-precomputed sampler constants ``(theta', zeta, eta, alpha)``.

    ``theta'`` is the pole-nudged exponent actually used — store THAT in
    the traced params so host and device agree bit-for-bit.  ``zeta`` is
    the generalized harmonic number ``H_{n,theta}``; ``eta``/``alpha``
    are the Gray et al. rejection-free inverse-CDF constants.  All three
    ride traced (f32) so ``n_keys`` / ``zipf_theta`` sweep as batch
    axes — per-cell values are recomputed here by the sweep engine.
    """
    n_keys = int(n_keys)
    theta = float(theta)
    if n_keys < 1:
        raise ValueError(f"zipf_consts: n_keys must be >= 1, got {n_keys}")
    if not np.isfinite(theta) or theta < 0.0:
        raise ValueError("zipf_consts: theta must be finite and >= 0, "
                         f"got {theta!r}")
    if abs(theta - 1.0) < _POLE_EPS:
        theta = 1.0 - _POLE_EPS if theta <= 1.0 else 1.0 + _POLE_EPS
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    zeta = float(np.sum(ranks ** -theta))
    zeta2 = float(1.0 + 0.5 ** theta) if n_keys >= 2 else zeta
    alpha = 1.0 / (1.0 - theta)
    denom = 1.0 - zeta2 / zeta
    # n_keys 1..2 degenerate: the tail branch is never taken; keep eta
    # finite so the traced constant stays well-defined.
    eta = (1.0 - (2.0 / n_keys) ** (1.0 - theta)) / denom \
        if n_keys > 2 and abs(denom) > 1e-12 else 1.0
    return theta, float(zeta), float(eta), float(alpha)


def zipf_key(u, n_keys, theta, zeta, eta, alpha):
    """Branchless O(1) Zipf(n_keys, theta) rank from a uniform ``u``.

    The Gray et al. / YCSB inverse-CDF approximation: exact for ranks 0
    and 1, a smooth power-law inverse for the tail.  Every argument may
    be traced (``n_keys`` included), so sweeps over key count and
    exponent batch inside one executable.  Returns i32 in
    ``[0, n_keys)``."""
    n = jnp.asarray(n_keys, jnp.float32)
    u = jnp.asarray(u, jnp.float32)
    uz = u * zeta
    zeta2 = 1.0 + 0.5 ** jnp.asarray(theta, jnp.float32)
    tail = jnp.floor(n * (eta * u - eta + 1.0) ** alpha)
    k = jnp.where(uz < 1.0, 0.0, jnp.where(uz < zeta2, 1.0, tail))
    return jnp.clip(k, 0.0, n - 1.0).astype(jnp.int32)


def key_to_lock(key, n_locks):
    """Bucketed key→lock index: ``key % n_locks`` — rank-preserving, so
    key 0 (hottest) always lands on lock 0 (the hot bucket) and hotter
    keys map to lower lock ids.  ``n_locks`` may be traced (the active
    lock count of a lock-count sweep cell)."""
    return jnp.mod(jnp.asarray(key, jnp.int32),
                   jnp.maximum(jnp.asarray(n_locks, jnp.int32), 1))


# --------------------------------------------------------------------------
# Per-(core, epoch) streams — the device-side contract
# --------------------------------------------------------------------------

def epoch_key_u(seed, core, epoch):
    """The key-stream uniform for (core, epoch) — pure counter draw."""
    return counter_uniform(stream_key(seed, STREAM_KEY), core, epoch)


def epoch_rw_u(seed, core, epoch):
    """The read/write-stream uniform for (core, epoch) — CREW policies
    classify an epoch as a write when this falls below the traced write
    fraction."""
    return counter_uniform(stream_key(seed, STREAM_RW), core, epoch)


def epoch_lock(seed, core, epoch, n_keys, theta, zeta, eta, alpha,
               n_locks):
    """The lock a (core, epoch) contends: Zipf key → bucket, end to end
    counter-pure.  This is the one composition the engine calls."""
    u = epoch_key_u(seed, core, epoch)
    return key_to_lock(zipf_key(u, n_keys, theta, zeta, eta, alpha),
                       n_locks)


# --------------------------------------------------------------------------
# Host reconstruction (tests / analysis)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnums=(1, 2))
def _u_grid(key, n_cores: int, n_epochs: int):
    cs = jnp.arange(n_cores, dtype=jnp.int32)
    es = jnp.arange(n_epochs, dtype=jnp.int32)
    return jax.vmap(lambda c: jax.vmap(
        lambda e: counter_uniform(key, c, e))(es))(cs)


def key_table(seed, n_cores: int, n_epochs: int, n_keys: int,
              theta: float) -> np.ndarray:
    """Host reconstruction of the device key stream: ``[c, e]`` is the
    Zipf key core ``c`` draws for epoch ``e`` — element-wise counter-
    pure, so the table is prefix-invariant in BOTH dimensions (growing
    it never perturbs existing entries)."""
    th, zeta, eta, alpha = zipf_consts(n_keys, theta)
    u = _u_grid(stream_key(seed, STREAM_KEY), n_cores, n_epochs)
    return np.asarray(zipf_key(u, n_keys, th, zeta, eta, alpha))


def lock_table(seed, n_cores: int, n_epochs: int, n_keys: int,
               theta: float, n_locks: int) -> np.ndarray:
    """Host reconstruction of the per-(core, epoch) lock ids the engine
    consumes (``key_table`` pushed through the bucket index)."""
    return np.asarray(key_to_lock(
        key_table(seed, n_cores, n_epochs, n_keys, theta), n_locks))


def rw_table(seed, n_cores: int, n_epochs: int,
             write_frac: float) -> np.ndarray:
    """Host reconstruction of the CREW write bits (1 = write epoch)."""
    u = np.asarray(_u_grid(stream_key(seed, STREAM_RW),
                           n_cores, n_epochs))
    return (u < write_frac).astype(np.int32)


def zipf_pmf(n_keys: int, theta: float) -> np.ndarray:
    """The exact target pmf ``P(key = k) ∝ 1/(k+1)^theta`` (moments
    tests compare empirical frequencies against this)."""
    th, zeta, _, _ = zipf_consts(n_keys, theta)
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    return ranks ** -th / zeta
