"""Stochastic workload generators — counter-based, pure-jnp, vmap-safe.

Two layers share one set of samplers:

* **Scalar unit samplers** (``think_gap``, ``service_unit``, ...): pure
  functions of uniforms/normals and *traced* distribution parameters,
  combined branchlessly over the distribution id — so the discrete-event
  simulator (``repro.core.simlock``) can sweep ``arrival_rate`` / ``cv``
  / ``mix`` / ``burstiness`` as traced batch axes inside ONE compiled
  executable per policy.
* **Host array generators** (``arrival_times``, ``service_times``):
  vectorized draws for the host-side serving sims and the trace recorder.

RNG discipline (the load-bearing invariant): every uniform/normal is a
pure function of ``(seed, stream, *indices)`` via ``jax.random.fold_in``
chains — there is **no sequential RNG state**.  Draw ``i`` has the same
value whether it is produced on device inside a vmapped sweep lane, on
the host by the trace recorder, or re-produced by a replayer; batching,
sharding and event interleaving cannot perturb the workload.  Streams
(``STREAM_*``) keep arrival, service, phase and class draws independent.

All mean-1 "unit" samplers scale an externally-calibrated mean, so
changing the *shape* of a distribution (cv, mix) never changes its mean.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Arrival processes.  "closed" = closed-loop deterministic think time
# (rate = 1/think); "poisson" = open-loop exponential gaps; "mmpp" =
# 2-state Markov-modulated Poisson (bursty on-off); "diurnal" = Poisson
# with a sinusoidal rate ramp.
ARRIVALS = {"closed": 0, "poisson": 1, "mmpp": 2, "diurnal": 3}
# Service-time distributions.  "bimodal" models a Get/Put mix: a short
# mode and a ``mix_scale``x longer mode with probability ``mix``.
SERVICES = {"det": 0, "exp": 1, "lognormal": 2, "bimodal": 3}

# Independent draw streams (fold_in'd into the seed).
STREAM_THINK = 0x7781
STREAM_SERVICE = 0x7782
STREAM_PHASE = 0x7783
STREAM_CLASS = 0x7784
STREAM_COLS = 0x7785
STREAM_STRAGGLE = 0x7786
# Fault-injection streams (repro.faults): holder preemption decisions /
# durations, core-churn on/off slots, straggler service spikes.
STREAM_PREEMPT = 0x7787
STREAM_CHURN = 0x7788
STREAM_SPIKE = 0x7789
# Key-sharded datastore streams (repro.workloads.keys): the per-epoch
# Zipf key draw and the CREW read/write classification bit.
STREAM_KEY = 0x778A
STREAM_RW = 0x778B


# --------------------------------------------------------------------------
# Counter-based keys and draws
# --------------------------------------------------------------------------

def stream_key(seed, stream: int):
    """Base key of one draw stream: fold_in(PRNGKey(seed), stream)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), stream)


def counter_key(key, *indices):
    """Fold traced indices into a stream key (a pure counter, no state)."""
    for ix in indices:
        key = jax.random.fold_in(key, ix)
    return key


def counter_uniform(key, *indices):
    """U[0,1) as a pure function of (stream key, indices)."""
    return jax.random.uniform(counter_key(key, *indices))


def counter_normal(key, *indices):
    return jax.random.normal(counter_key(key, *indices))


@partial(jax.jit, static_argnums=(1, 2))
def _block(key, n: int, kind: str):
    ix = jnp.arange(n, dtype=jnp.int32)
    if kind == "normal":
        return jax.vmap(lambda i: counter_normal(key, i))(ix)
    return jax.vmap(lambda i: counter_uniform(key, i))(ix)


def _pad_pow2(n: int) -> int:
    return 1 << max(6, int(n - 1).bit_length())


def uniform_block(seed, stream: int, n: int) -> np.ndarray:
    """Host-side block of counter-based uniforms: element ``i`` is
    ``counter_uniform(stream_key(seed, stream), i)`` — independent of
    ``n`` (the block is drawn at the next power of two and sliced), so
    growing a trace never perturbs its prefix."""
    return np.asarray(_block(stream_key(seed, stream), _pad_pow2(n),
                             "uniform"))[:n].astype(np.float64)


def normal_block(seed, stream: int, n: int) -> np.ndarray:
    return np.asarray(_block(stream_key(seed, stream), _pad_pow2(n),
                             "normal"))[:n].astype(np.float64)


# --------------------------------------------------------------------------
# Unit samplers (mean 1, scalar or vectorized; jnp and numpy agree)
# --------------------------------------------------------------------------

def exp_unit(u):
    """Exp(1) from a uniform (inverse CDF; safe at u=1-eps)."""
    return -jnp.log1p(-u)


def lognormal_unit(z, cv):
    """Mean-1 lognormal with coefficient of variation ``cv`` from a
    standard normal ``z`` (sigma^2 = ln(1+cv^2), mu = -sigma^2/2)."""
    s2 = jnp.log1p(jnp.square(cv))
    return jnp.exp(jnp.sqrt(s2) * z - 0.5 * s2)


def bimodal_unit(u, mix, mix_scale):
    """Mean-1 two-point Get/Put mix: with probability ``mix`` the long
    mode (``mix_scale`` x the short one), else the short mode."""
    short = 1.0 / ((1.0 - mix) + mix * mix_scale)
    return jnp.where(u < mix, short * mix_scale, short)


def service_unit(u, z, dist, cv, mix, mix_scale):
    """Mean-1 service multiplier, branchless over the SERVICES id
    (``dist`` may be traced — all four samplers are cheap scalar math)."""
    out = jnp.float32(1.0)                                   # det
    out = jnp.where(dist == SERVICES["exp"], exp_unit(u), out)
    out = jnp.where(dist == SERVICES["lognormal"],
                    lognormal_unit(z, cv), out)
    out = jnp.where(dist == SERVICES["bimodal"],
                    bimodal_unit(u, mix, mix_scale), out)
    return out


def mmpp_rates(rate, burstiness):
    """On/off rates of the 2-state MMPP with long-run mean ``rate``.
    Phase residence is counted in *draws* (symmetric flip probability),
    so the off phase occupies proportionally more wall time and the
    time-average rate is the HARMONIC mean of the two:
    on = ``burstiness`` x off, 2/(1/on + 1/off) = rate."""
    r_off = rate * (1.0 + burstiness) / (2.0 * burstiness)
    return burstiness * r_off, r_off


def phase_flip(u, on, burst_len):
    """One MMPP phase step: flip with probability 1/burst_len (mean
    phase residence = ``burst_len`` draws).  ``on`` is i32 0/1."""
    flip = u < 1.0 / jnp.maximum(burst_len, 1.0)
    return jnp.where(flip, 1 - on, on)


def diurnal_rate(rate, amp, phase01):
    """Sinusoidal rate ramp: rate * (1 + amp*sin(2*pi*phase01)),
    floored at 5% of the mean so the gap stays finite."""
    mod = 1.0 + amp * jnp.sin(2.0 * jnp.pi * phase01)
    return jnp.maximum(rate * mod, 0.05 * rate)


def think_gap(u, process, rate, on, burstiness, phase01, amp):
    """One inter-arrival / think gap (mean 1/rate), branchless over the
    ARRIVALS id.  ``on`` is the MMPP phase bit; ``phase01`` the diurnal
    cycle position in [0,1)."""
    e1 = exp_unit(u)
    gap = 1.0 / rate                                         # closed
    gap = jnp.where(process == ARRIVALS["poisson"], e1 / rate, gap)
    r_on, r_off = mmpp_rates(rate, burstiness)
    gap = jnp.where(process == ARRIVALS["mmpp"],
                    e1 / jnp.where(on == 1, r_on, r_off), gap)
    gap = jnp.where(process == ARRIVALS["diurnal"],
                    e1 / diurnal_rate(rate, amp, phase01), gap)
    return gap


def phase_bits(seed, n, burst_len, *, core=None, stream=STREAM_PHASE):
    """The MMPP phase sequence for draws 0..n-1 as a host array.  Flip
    ``i`` is counter-based, so the stateful on/off walk is a cumulative
    XOR — the host can reconstruct exactly what a device-side lane (or a
    different host sim) saw.  ``core`` namespaces per-client streams."""
    if n == 0:
        return np.zeros(0, np.int32)
    key = stream_key(seed, stream)
    if core is not None:
        key = counter_key(key, core)
    u = np.asarray(_block(key, _pad_pow2(n), "uniform"))[:n]
    init_on = (u[0] < 0.5).astype(np.int32)
    flips = (u < 1.0 / max(float(burst_len), 1.0)).astype(np.int32)
    flips[0] = 0                       # draw 0 sets the initial phase
    return (init_on + np.cumsum(flips)) % 2


# --------------------------------------------------------------------------
# Per-(core, epoch) draws — the contract between the device-side lock
# simulator and host-side reconstruction.  simlock calls the scalar forms
# per event; epoch_scale_tables re-derives the identical values on the
# host (pure counters: no event ordering, batching or sharding involved).
# --------------------------------------------------------------------------

def epoch_think_u(seed, core, epoch):
    return counter_uniform(stream_key(seed, STREAM_THINK), core, epoch)


def epoch_service_uz(seed, core, epoch):
    u = counter_uniform(stream_key(seed, STREAM_SERVICE), core, epoch)
    z = counter_normal(stream_key(seed, STREAM_SERVICE ^ 0x40000),
                       core, epoch)
    return u, z


def epoch_phase_u(seed, core, epoch):
    return counter_uniform(stream_key(seed, STREAM_PHASE), core, epoch)


def epoch_scale_tables(seed, n_cores: int, n_epochs: int, *, process,
                       rate, cv=1.0, mix=0.0, mix_scale=10.0,
                       burstiness=1.0, burst_len=8.0, service="det"):
    """Host reconstruction of the simulator's per-epoch workload draws.

    Returns ``(think, svc)`` — f64[n_cores, n_epochs] think-gap and
    service-unit multipliers, bit-identical to what a ``wl=True``
    ``simlock`` run with the same traced params applies at each core's
    epoch ``e`` (epoch 0 = the initial draw).  ``service`` may be one
    SERVICES name or a per-core sequence of names (the simulator's
    ``wl_service_per_core`` table — multi-class tenants with different
    service shapes per core).  The diurnal ramp is the one process this
    cannot reproduce (its rate depends on sim *time*, not the epoch
    counter) — requesting it raises."""
    if process == "diurnal":
        raise ValueError("diurnal draws depend on simulated time; only "
                         "counter-pure processes can be reconstructed")
    pid = ARRIVALS[process]
    if isinstance(service, str):
        sid = SERVICES[service]
    else:
        if len(service) != n_cores:
            raise ValueError(f"per-core service list has {len(service)} "
                             f"entries for {n_cores} cores")
        # One id per core, broadcast over the epoch axis below.
        sid = jnp.asarray([SERVICES[s] for s in service],
                          jnp.int32)[:, None]
    cores = jnp.arange(n_cores, dtype=jnp.int32)
    epochs = jnp.arange(n_epochs, dtype=jnp.int32)

    def per_core(c):
        u_t = jax.vmap(lambda e: epoch_think_u(seed, c, e))(epochs)
        u_s, z_s = jax.vmap(lambda e: epoch_service_uz(seed, c, e))(epochs)
        return u_t, u_s, z_s

    u_t, u_s, z_s = jax.vmap(per_core)(cores)
    on = np.stack([phase_bits(seed, n_epochs, burst_len, core=int(c))
                   for c in range(n_cores)]) if n_epochs else \
        np.zeros((n_cores, 0), np.int32)
    think = think_gap(jnp.asarray(u_t), pid, rate, jnp.asarray(on),
                      burstiness, 0.0, 0.0)
    svc = service_unit(jnp.asarray(u_s), jnp.asarray(z_s), sid, cv, mix,
                       mix_scale)
    return (np.asarray(think, np.float64), np.asarray(svc, np.float64))


# --------------------------------------------------------------------------
# Host-level specs + array generators (the serving sims / trace recorder)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """An arrival process in host units (events per second)."""

    process: str = "poisson"
    rate: float = 1.0             # mean arrivals/sec
    burstiness: float = 1.0       # MMPP on/off rate ratio (1 = plain)
    burst_len: float = 8.0        # mean draws per MMPP phase
    amp: float = 0.0              # diurnal amplitude in [0,1)
    period: float = 0.0           # diurnal period (sec); 0 = flat

    def __post_init__(self):
        if self.process not in ARRIVALS:
            raise ValueError(f"unknown arrival process {self.process!r}; "
                             f"one of {sorted(ARRIVALS)}")


@dataclasses.dataclass(frozen=True)
class ServiceSpec:
    """A service-time distribution in host units (seconds)."""

    dist: str = "det"
    mean: float = 1.0
    cv: float = 1.0               # lognormal coefficient of variation
    mix: float = 0.0              # bimodal: P(long mode)
    mix_scale: float = 10.0       # bimodal: long/short ratio

    def __post_init__(self):
        if self.dist not in SERVICES:
            raise ValueError(f"unknown service dist {self.dist!r}; "
                             f"one of {sorted(SERVICES)}")


# Shape of the legacy ``rng.lognormal(log(m), 0.3)`` service draw the
# dispatch sim used before this package existed: cv = sqrt(exp(0.09)-1),
# and its *mean* was m * exp(0.045) (m was the median) — ServiceSpec is
# mean-parameterized, so the legacy calibration needs the inflation too.
LEGACY_LOGNORMAL_CV = float(np.sqrt(np.expm1(0.3 ** 2)))
LEGACY_LOGNORMAL_MEAN = float(np.exp(0.5 * 0.3 ** 2))


def arrival_times(spec: ArrivalSpec, duration: float, seed: int,
                  *, stream: int = STREAM_THINK) -> np.ndarray:
    """Arrival times in [0, duration) — deterministic per (spec, seed).

    Gap ``i`` uses counter draw ``i`` of ``stream``; the MMPP phase walk
    is the counter-based cumulative-XOR of :func:`phase_bits`; the
    diurnal ramp modulates by the arrival's own position in the cycle.
    """
    r_on, _ = mmpp_rates(spec.rate, spec.burstiness)
    r_max = max(spec.rate * (1.0 + abs(spec.amp)), float(r_on), 1e-9)
    n = int(duration * r_max * 1.4) + 64
    u = uniform_block(seed, stream, n)
    e1 = -np.log1p(-u)
    if spec.process == "closed":
        gaps = np.full(n, 1.0 / spec.rate)
    elif spec.process == "poisson":
        gaps = e1 / spec.rate
    elif spec.process == "mmpp":
        # Phase stream is the gap stream xor a high bit — never collides
        # with another STREAM_* constant.
        on = phase_bits(seed, n, spec.burst_len, stream=stream ^ 0x10000)
        r_on, r_off = mmpp_rates(spec.rate, spec.burstiness)
        gaps = e1 / np.where(on == 1, r_on, r_off)
    else:  # diurnal: the rate seen by gap i follows the running clock
        # Scalar host math (the loop is inherently sequential in t; a
        # per-gap jnp dispatch here was measured ~17x slower).
        import math
        period = spec.period if spec.period > 0 else duration
        gaps = np.empty(n)
        t = 0.0
        for i in range(n):
            mod = 1.0 + spec.amp * math.sin(
                2.0 * math.pi * ((t / period) % 1.0))
            r = max(spec.rate * mod, 0.05 * spec.rate)
            gaps[i] = e1[i] / r
            t += gaps[i]
    t = np.cumsum(gaps)
    return t[t < duration]


def service_times(spec: ServiceSpec, n: int, seed: int,
                  *, stream: int = STREAM_SERVICE) -> np.ndarray:
    """``n`` service times (mean ``spec.mean``), counter-based per index."""
    u = uniform_block(seed, stream, n)
    z = normal_block(seed, stream ^ 0x40000, n)
    unit = np.asarray(service_unit(u, z, SERVICES[spec.dist],
                                   spec.cv, spec.mix, spec.mix_scale))
    return spec.mean * unit


def client_think_gaps(seed, client: int, n: int,
                      *, stream: int = STREAM_THINK) -> np.ndarray:
    """Exp(1) think gaps for one closed-loop client — counter-based per
    (client, request index); scale by the mean think time at the call."""
    key = counter_key(stream_key(seed, stream), client)
    u = np.asarray(_block(key, _pad_pow2(n), "uniform"))[:n]
    return -np.log1p(-u.astype(np.float64))


def straggle_uniforms(seed, replica: int, n: int,
                      *, stream: int = STREAM_STRAGGLE) -> np.ndarray:
    """Straggler-decision uniforms for one replica/pod: element ``i`` is
    pure in ``(seed, replica, i)`` — the draw for step ``i`` is the same
    whatever the horizon, the pod count, or the commit interleaving
    (replaces the straggler sim's last ad-hoc ``np.random`` state)."""
    key = counter_key(stream_key(seed, stream), replica)
    u = np.asarray(_block(key, _pad_pow2(n), "uniform"))[:n]
    return u.astype(np.float64)


def choice(values, n: int, seed: int, *, stream: int = STREAM_COLS,
           weights=None) -> np.ndarray:
    """Counter-based categorical draw over ``values`` (uniform unless
    ``weights``); replaces the serving sims' ad-hoc ``rng.choice``."""
    values = np.atleast_1d(np.asarray(values))
    u = uniform_block(seed, stream, n)
    if weights is None:
        idx = np.minimum((u * len(values)).astype(np.int64),
                         len(values) - 1)
    else:
        w = np.asarray(weights, np.float64)
        cum = np.cumsum(w / w.sum())
        idx = np.searchsorted(cum, u, side="right")
        idx = np.minimum(idx, len(values) - 1)
    return values[idx]
