"""Workload trace record/replay — one npz file, bit-deterministic.

A :class:`Trace` is the materialized form of a workload: sorted arrival
times, per-request service times, a class id per request (multi-class
mixes), plus arbitrary aligned extra columns (the serving engine stores
``prompt_len`` / ``new_tokens`` here).  Because every generator draw is
counter-based (:mod:`repro.workloads.generators`), ``generate`` is a
pure function of its specs + seed — recording a trace and re-generating
it later are bit-identical, and every consumer (dispatch sim, serving
engine, a plot script) replaying one trace sees exactly one workload.

File format (``save``/``load``): a single ``.npz`` with the three core
arrays, one ``col_<name>`` array per extra column, and a json-encoded
``meta`` blob (class names, per-class SLOs, generating specs, format
version).  No pickling — traces are portable and diff-able.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import numpy as np

from repro.workloads.generators import (STREAM_CLASS, STREAM_SERVICE,
                                        ArrivalSpec, ServiceSpec,  # noqa: F401
                                        arrival_times, service_times)

FORMAT_VERSION = 1
STREAM_COLS_PROMPT = STREAM_CLASS ^ 0x20000
STREAM_COLS_TOKENS = STREAM_CLASS ^ 0x30000


@dataclasses.dataclass
class Trace:
    """One recorded workload.  ``klass`` indexes ``classes``/``slo``."""

    arrival_t: np.ndarray                 # f64[n], sorted
    service_s: np.ndarray                 # f64[n]
    klass: np.ndarray                     # i32[n]
    classes: tuple = ("default",)
    slo: np.ndarray = None                # f64[K] per-class SLO (or None)
    cols: dict = dataclasses.field(default_factory=dict)
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        n = len(self.arrival_t)
        for name, arr in [("service_s", self.service_s),
                          ("klass", self.klass)] + list(self.cols.items()):
            if len(arr) != n:
                raise ValueError(f"column {name!r} has {len(arr)} rows, "
                                 f"trace has {n}")

    def __len__(self):
        return len(self.arrival_t)

    def rows(self):
        """Iterate (arrival_t, service_s, klass, cols-dict) per request."""
        for i in range(len(self)):
            yield (float(self.arrival_t[i]), float(self.service_s[i]),
                   int(self.klass[i]),
                   {k: v[i] for k, v in self.cols.items()})


def generate(arrival: ArrivalSpec, service, duration: float, seed: int,
             *, classes=None, cols=None) -> Trace:
    """Materialize a workload trace (deterministic per arguments).

    ``service`` is one :class:`ServiceSpec`, or — with ``classes`` a
    :class:`repro.workloads.clients.WorkloadMix` — ignored in favor of
    the per-class specs.  ``cols`` maps column names to callables
    ``f(n, seed) -> array`` (e.g. counter-based ``generators.choice``).
    """
    t = arrival_times(arrival, duration, seed)
    n = len(t)
    meta = {"version": FORMAT_VERSION, "seed": int(seed),
            "duration": float(duration),
            "arrival": dataclasses.asdict(arrival)}
    if classes is not None:
        kl = classes.class_ids(n, seed)
        per = np.zeros(n)
        for k, cls in enumerate(classes.classes):
            # Per-class service stream: a high-nibble offset that cannot
            # collide with any STREAM_* constant (0x778x block).
            svc = service_times(cls.service, n, seed,
                                stream=STREAM_SERVICE ^ (0x1000 * (k + 1)))
            per = np.where(kl == k, svc, per)
        names = tuple(c.name for c in classes.classes)
        slo = np.asarray([c.slo for c in classes.classes], np.float64)
        meta["services"] = [dataclasses.asdict(c.service)
                            for c in classes.classes]
        trace = Trace(t, per, kl.astype(np.int32), names, slo)
    else:
        svc = service_times(service, n, seed)
        meta["services"] = [dataclasses.asdict(service)]
        trace = Trace(t, svc, np.zeros(n, np.int32))
    trace.meta = meta
    for name, fn in (cols or {}).items():
        trace.cols[name] = np.asarray(fn(n, seed))
    return trace


def save(path, trace: Trace) -> Path:
    """Write one npz (arrays + json meta); returns the path."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    meta = dict(trace.meta, version=FORMAT_VERSION,
                classes=list(trace.classes))
    arrays = {"arrival_t": trace.arrival_t, "service_s": trace.service_s,
              "klass": trace.klass,
              "meta": np.frombuffer(
                  json.dumps(meta, sort_keys=True).encode(), np.uint8)}
    if trace.slo is not None:
        arrays["slo"] = np.asarray(trace.slo, np.float64)
    for name, arr in trace.cols.items():
        arrays[f"col_{name}"] = np.asarray(arr)
    with open(path, "wb") as f:
        np.savez(f, **arrays)
    return path


def load(path) -> Trace:
    with np.load(path) as z:
        meta = json.loads(bytes(z["meta"].tobytes()).decode())
        if meta.get("version", 0) > FORMAT_VERSION:
            raise ValueError(f"trace {path} has format version "
                             f"{meta['version']} > {FORMAT_VERSION}")
        cols = {k[len("col_"):]: z[k] for k in z.files
                if k.startswith("col_")}
        return Trace(z["arrival_t"], z["service_s"], z["klass"],
                     tuple(meta.pop("classes", ("default",))),
                     z["slo"] if "slo" in z.files else None, cols, meta)


def request_columns(prompt_lens, new_tokens):
    """Standard serving-engine columns (counter-based choices)."""
    from repro.workloads.generators import choice
    return {
        "prompt_len": lambda n, seed: choice(
            prompt_lens, n, seed, stream=STREAM_COLS_PROMPT),
        "new_tokens": lambda n, seed: choice(
            new_tokens, n, seed, stream=STREAM_COLS_TOKENS),
    }
