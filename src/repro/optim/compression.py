"""Int8 gradient compression with error feedback (distributed-optimization
trick for the cross-pod all-reduce).

Cross-pod gradient all-reduce over DCN is the multi-pod bottleneck
(EXPERIMENTS.md §Roofline: the 'pod' axis all-reduce).  We quantize each
leaf to int8 with a per-block fp32 scale before the reduce and keep the
quantization residual in an **error-feedback** buffer added to the next
step's gradient (Seide et al. / EF-SGD) so compression error doesn't bias
the descent direction.

``compress -> (psum over 'pod') -> decompress`` drops cross-pod bytes 4x
(bf16) to ~4.06x (int8 payload + 1/block scales).  In-pod reduction stays
full precision.  Pure-jnp, vmappable, and exercised end-to-end by the
trainer tests; on the dry-run mesh the quantized psum shows up in the
collective schedule with 1/4 the bytes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _blocked(x, block):
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block), pad


def quantize_int8(x, *, block: int = 256):
    """-> (q int8 [n,block], scale fp32 [n,1], meta) with error residual."""
    xb, pad = _blocked(x.astype(jnp.float32), block)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale, (x.shape, pad)


def dequantize_int8(q, scale, meta):
    shape, pad = meta
    xb = q.astype(jnp.float32) * scale
    flat = xb.reshape(-1)
    if pad:
        flat = flat[:-pad] if pad else flat
    return flat.reshape(shape)


def compress_tree(grads, error_buf=None, *, block: int = 256):
    """Returns (payload tree for the reduce, new error-feedback buffers).

    payload leaves are (q, scale, meta); error_buf holds the residual
    g - dequant(quant(g + e_prev)) per leaf.
    """
    if error_buf is None:
        error_buf = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                 grads)

    def one(g, e):
        g_ef = g.astype(jnp.float32) + e
        q, s, meta = quantize_int8(g_ef, block=block)
        deq = dequantize_int8(q, s, meta)
        return (q, s, meta), g_ef - deq

    pairs = jax.tree.map(one, grads, error_buf)
    payload = jax.tree.map(lambda t: t[0], pairs,
                           is_leaf=lambda t: isinstance(t, tuple)
                           and len(t) == 2 and isinstance(t[0], tuple))
    new_err = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda t: isinstance(t, tuple)
                           and len(t) == 2 and isinstance(t[0], tuple))
    return payload, new_err


def decompress_tree(payload, like):
    def one(p, g):
        q, s, meta = p
        return dequantize_int8(q, s, meta).astype(g.dtype)

    return jax.tree.map(one, payload, like,
                        is_leaf=lambda t: isinstance(t, tuple)
                        and len(t) == 3)


def psum_compressed(grads, axis_name, error_buf=None, *, block: int = 256):
    """Inside shard_map/pmap: int8-compress, psum, decompress, EF update.

    The int8 payload is summed as int32 (no overflow for <=2^23 pods) and
    rescaled by the max scale — a standard stochastic-rounding-free EF-SGD
    variant; the residual stays local.
    """
    payload, new_err = compress_tree(grads, error_buf, block=block)

    def reduce_one(p):
        q, s, meta = p
        s_max = jax.lax.pmax(s, axis_name)
        # renormalize local q to the shared scale before summing
        q_shared = jnp.round(q.astype(jnp.float32) * (s / s_max))
        total = jax.lax.psum(q_shared.astype(jnp.int32), axis_name)
        return dequantize_int8(total.astype(jnp.float32), s_max, meta)

    summed = jax.tree.map(reduce_one, payload,
                          is_leaf=lambda t: isinstance(t, tuple)
                          and len(t) == 3)
    return summed, new_err
