"""AdamW with configurable moment dtype + global-norm clipping.

No optax dependency.  Moments may be stored in bf16 (``opt_state_dtype``) for
the largest archs (llama3-405b on a single 256-chip pod is memory-bound on
optimizer state; see DESIGN.md §6); math always runs in fp32.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import DTYPES


class AdamWState(NamedTuple):
    m: dict
    v: dict
    count: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"

    def init(self, params) -> AdamWState:
        dt = DTYPES[self.state_dtype]
        z = lambda p: jnp.zeros(p.shape, dt)
        return AdamWState(m=jax.tree.map(z, params),
                          v=jax.tree.map(z, params),
                          count=jnp.zeros((), jnp.int32))

    def update(self, grads, state: AdamWState, params, lr):
        dt = DTYPES[self.state_dtype]
        c = state.count + 1
        bc1 = 1.0 - self.b1 ** c.astype(jnp.float32)
        bc2 = 1.0 - self.b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            mf = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * gf
            vf = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * gf * gf
            step = lr * (mf / bc1) / (jnp.sqrt(vf / bc2) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # no decay on norms/biases
                step = step + lr * self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step).astype(p.dtype), \
                mf.astype(dt), vf.astype(dt)

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(new_m, new_v, c)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(x.astype(jnp.float32) ** 2)
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale)
                        .astype(x.dtype), tree), n


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = base_lr * (s + 1) / max(warmup, 1)
        import numpy as np
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(np.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr
