"""Model + shape configuration schema shared by all ten architectures."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense|moe|hybrid|ssm|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    block_pattern: tuple = ("attn",)   # cycled over layers
    activation: str = "swiglu"         # swiglu|geglu|gelu
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    expert_parallel: bool = False      # shard experts over 'data' (EP)
    qkv_bias: bool = False
    rope_theta: float = 1e4
    local_window: int = 0              # window for "local_attn" blocks
    causal: bool = True                # False: encoder-only (hubert)
    frontend: str = "none"             # none|audio_stub|vision_stub
    n_patches: int = 0                 # vlm: prepended patch embeddings
    emb_scale: bool = False            # gemma: embeddings * sqrt(d)
    logits_softcap: float = 0.0        # grok-style tanh soft-cap
    norm_eps: float = 1e-6
    rnn_width: int = 0                 # rglru recurrence width
    conv_width: int = 4                # rglru temporal conv
    dtype: str = "bfloat16"            # activation/compute dtype
    param_dtype: str = "float32"       # at-rest parameter dtype
    opt_state_dtype: str = "float32"   # Adam moment dtype (bf16 for 405B)
    grad_accum_dtype: str = "float32"  # accumulation buffer dtype
    seq_parallel: bool = False         # shard residual-stream seq over TP
    tie_embeddings: bool = False
    remat: bool = True
    scan_layers: bool = True           # scan over uniform stacks
    attn_q_block: int = 512            # query-chunk size (flash-style XLA path)

    # ------------------------------------------------------------------
    def blocks(self) -> tuple:
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    @property
    def uniform_stack(self) -> bool:
        return len(set(self.block_pattern)) == 1

    @property
    def attn_q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def sub_quadratic(self) -> bool:
        kinds = set(self.blocks())
        return "attn" not in kinds

    @property
    def has_decode(self) -> bool:
        return self.causal

    def compute_dtype(self):
        return DTYPES[self.dtype]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train|prefill|decode
    seq_len: int
    global_batch: int
    microbatches: int = 1     # gradient-accumulation steps (train only)

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


# The assigned input-shape set (identical for all ten LM-family archs).
def standard_shapes(train_micro: int = 1) -> dict:
    return {
        "train_4k": ShapeSpec("train_4k", "train", 4096, 256, train_micro),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
        "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
        "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
    }


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether a (arch, shape) dry-run cell applies (DESIGN.md §5 skips)."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch skipped at 500k (quadratic)"
    return True, ""
