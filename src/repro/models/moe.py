"""Capacity-based top-k MoE (GShard-style) with GSPMD-friendly dispatch.

The [tokens, experts, capacity] one-hot dispatch tensor of the original
GShard formulation is quadratically large at our shapes, so dispatch is done
as a *local permutation per data shard*:

* inside ``shard_map`` over the data axes (token dim): local top-k routing,
  position-in-expert via a cumulative one-hot (small: T_loc·k × E), and a
  scatter-add into a local ``[E, C_loc, D]`` buffer (tokens over local
  capacity are dropped — the paper-standard "token dropping" with
  ``capacity_factor`` headroom);
* *outside* shard_map, the expert FFN runs as plain batched einsums so GSPMD
  applies the usual FSDP/TP sharding to the expert weights (ff over 'model',
  embed over 'data'), exactly like the dense FFN path;
* a second local shard_map gathers and gate-combines the outputs.

Off-mesh (smoke tests) the same local functions run directly.  With
``expert_parallel`` rules, the expert dim of the buffers/weights shards over
'data' instead and GSPMD inserts the all-to-alls.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import current_rules
from repro.models.config import ModelConfig

try:  # jax >= 0.6 public API
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


def moe_schema(cfg: ModelConfig) -> dict:
    from repro.models.layers import PSpec  # local import (cycle)

    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    sch = {
        "router": PSpec((d, e), ("norm", "norm2"), ("normal", s_in)),
        "w1": PSpec((e, d, f), ("experts", "embed", "ff"), ("normal", s_in)),
        "w2": PSpec((e, f, d), ("experts", "ff", "embed"), ("normal", s_out)),
    }
    if cfg.activation in ("swiglu", "geglu"):
        sch["wg"] = PSpec((e, d, f), ("experts", "embed", "ff"),
                          ("normal", s_in))
    return sch


def _capacity(tokens_local: int, cfg: ModelConfig) -> int:
    cap = int(math.ceil(tokens_local * cfg.top_k * cfg.capacity_factor
                        / cfg.n_experts))
    return max(8, -(-cap // 8) * 8)  # round up to 8


def _route_local(x, router, cfg: ModelConfig, capacity: int):
    """x: [T, D] local tokens -> dispatch buffer + combine metadata."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", x, router.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)          # [T,E] fp32
    gate, idx = jax.lax.top_k(probs, k)              # [T,k]
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # Position in expert: priority order = (slot k, then token order), the
    # GShard convention (first choices beat second choices).
    idx_f = idx.transpose(1, 0).reshape(-1)          # [k*T], k-major
    onehot = jax.nn.one_hot(idx_f, e, dtype=jnp.int32)     # [k*T, E]
    pos_f = jnp.cumsum(onehot, axis=0) - onehot      # positions before me
    pos_f = jnp.sum(pos_f * onehot, axis=-1)         # [k*T]
    keep_f = pos_f < capacity
    pos = pos_f.reshape(k, t).transpose(1, 0)        # [T,k]
    keep = keep_f.reshape(k, t).transpose(1, 0)      # [T,k]

    # Scatter tokens into [E, C, D].
    buf = jnp.zeros((e, capacity, d), x.dtype)
    e_flat = idx.reshape(-1)
    p_flat = jnp.where(keep, pos, capacity - 1).reshape(-1)
    w_flat = keep.reshape(-1)
    contrib = jnp.repeat(x, k, axis=0) * w_flat[:, None].astype(x.dtype)
    buf = buf.at[e_flat, p_flat].add(contrib)

    # Aux load-balance loss terms (GShard): mean fraction & mean prob.
    density = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), 0)
    prob_mean = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * prob_mean) * e
    return buf, (idx, pos, keep, gate), aux


def _combine_local(out_buf, meta, dtype):
    idx, pos, keep, gate = meta
    # out_buf: [E, C, D]; gather each (token, k) slot and gate-combine.
    y = out_buf[idx, pos]                            # [T,k,D]
    w = (gate * keep).astype(jnp.float32)
    return jnp.einsum("tkd,tk->td", y.astype(jnp.float32), w).astype(dtype)


def moe_apply(p, x, cfg: ModelConfig):
    """x: [B,S,D] -> ([B,S,D], aux_loss scalar)."""
    dtype = cfg.compute_dtype()
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    rules = current_rules()

    # Weight-stationary decode replicates the token batch: routing/dispatch
    # are tiny and run replicated; only the expert einsums (against the
    # stationary 2D-sharded weights) touch sharded dims.
    ws_decode = rules is not None and rules.table.get("batch") is None

    if rules is None or rules.mesh is None or ws_decode:
        cap = _capacity(b * s, cfg)
        buf, meta, aux = _route_local(xf, p["router"], cfg, cap)
        out_buf = _expert_ffn(p, buf, cfg, dtype)
        y = _combine_local(out_buf, meta, dtype)
        return y.reshape(b, s, d), aux

    mesh = rules.mesh
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    shards = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    t_local = (b * s) // shards
    cap = _capacity(t_local, cfg)
    P = jax.sharding.PartitionSpec

    def dispatch(xl, router):
        buf, meta, aux = _route_local(xl, router, cfg, cap)
        return buf, meta, aux[None]

    buf, meta, aux = shard_map(
        dispatch, mesh,
        in_specs=(P(data_axes, None), P(None, None)),
        out_specs=(P(None, data_axes, None),
                   (P(data_axes, None), P(data_axes, None),
                    P(data_axes, None), P(data_axes, None)),
                   P(data_axes)))(xf, p["router"].astype(dtype))

    out_buf = _expert_ffn(p, buf, cfg, dtype)

    def combine(ob, idx, pos, keep, gate):
        return _combine_local(ob, (idx, pos, keep, gate), dtype)

    y = shard_map(
        combine, mesh,
        in_specs=(P(None, data_axes, None), P(data_axes, None),
                  P(data_axes, None), P(data_axes, None),
                  P(data_axes, None)),
        out_specs=P(data_axes, None))(out_buf, *meta)
    return y.reshape(b, s, d), jnp.mean(aux)


def _expert_ffn(p, buf, cfg: ModelConfig, dtype):
    """buf: [E, C, D] -> [E, C, D]; plain einsums => GSPMD shards weights."""
    from repro.dist.sharding import constrain

    buf = constrain(buf, "experts", "moe_cap", "act_embed")
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(dtype),
                   preferred_element_type=jnp.float32).astype(dtype)
    if cfg.activation in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dtype),
                       preferred_element_type=jnp.float32)
        act = jax.nn.silu if cfg.activation == "swiglu" else \
            (lambda v: jax.nn.gelu(v, approximate=True))
        h = act(g).astype(dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(dtype)
    h = constrain(h, "experts", "moe_cap", "ff")
    out = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(dtype),
                     preferred_element_type=jnp.float32).astype(dtype)
    return constrain(out, "experts", "moe_cap", "act_embed")
