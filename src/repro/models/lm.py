"""Unified LM: schema-driven parameters, forward / prefill / decode steps.

One :class:`~repro.models.config.ModelConfig` instantiates any of the ten
assigned architectures: a per-layer *block pattern* picks the mixer
("attn" | "local_attn" | "rglru" | "mlstm" | "slstm"); uniform stacks are
``lax.scan``-ed over stacked parameters (compile-time control at 126 layers),
mixed stacks unroll.  Modality frontends are stubs per the assignment:
``audio_stub`` consumes precomputed frame embeddings, ``vision_stub``
prepends precomputed patch embeddings to the token embeddings.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import constrain, current_rules
from repro.models import rglru, xlstm
from repro.models.config import DTYPES, ModelConfig
from repro.models.layers import (PSpec, attn_block_apply, attn_block_decode,
                                 attn_block_prefill, attn_cache_schema,
                                 attn_schema, ein, rms_norm)

BLOCK_SCHEMAS = {
    "attn": lambda cfg: attn_schema(cfg, local=False),
    "local_attn": lambda cfg: attn_schema(cfg, local=True),
    "rglru": rglru.rglru_schema,
    "mlstm": xlstm.mlstm_schema,
    "slstm": xlstm.slstm_schema,
}

BLOCK_APPLY = {
    "attn": partial(attn_block_apply, local=False),
    "local_attn": partial(attn_block_apply, local=True),
    "rglru": rglru.rglru_block_apply,
    "mlstm": xlstm.mlstm_block_apply,
    "slstm": xlstm.slstm_block_apply,
}

BLOCK_PREFILL = {
    "attn": partial(attn_block_prefill, local=False),
    "local_attn": partial(attn_block_prefill, local=True),
    "rglru": rglru.rglru_block_prefill,
    "mlstm": xlstm.mlstm_block_prefill,
    "slstm": xlstm.slstm_block_prefill,
}

BLOCK_DECODE = {
    "attn": partial(attn_block_decode, local=False),
    "local_attn": partial(attn_block_decode, local=True),
    "rglru": rglru.rglru_block_decode,
    "mlstm": xlstm.mlstm_block_decode,
    "slstm": xlstm.slstm_block_decode,
}


def _is_pspec(x):
    return isinstance(x, PSpec)


def _cache_schema_for(kind, cfg, batch, t_cache):
    if kind in ("attn", "local_attn"):
        return attn_cache_schema(cfg, batch, t_cache, kind == "local_attn")
    if kind == "rglru":
        return rglru.rglru_cache_schema(cfg, batch)
    if kind == "mlstm":
        return xlstm.mlstm_cache_schema(cfg, batch)
    if kind == "slstm":
        return xlstm.slstm_cache_schema(cfg, batch)
    raise KeyError(kind)


# ---------------------------------------------------------------------------
# Schema / init / sharding specs
# ---------------------------------------------------------------------------

def _scanned(cfg: ModelConfig) -> bool:
    return cfg.scan_layers and cfg.uniform_stack and cfg.n_layers > 1


def _stack(schema, n):
    return jax.tree.map(
        lambda ps: PSpec((n,) + tuple(ps.shape), ("layers",) + tuple(ps.axes),
                         ps.init),
        schema, is_leaf=_is_pspec)


def build_schema(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab
    sch = {}
    if cfg.frontend != "audio_stub":
        sch["embed"] = PSpec((v, d), ("vocab", "embed"), ("normal", 1.0))
    sch["final_ln"] = PSpec((d,), ("norm",), ("zeros",))
    if not cfg.tie_embeddings or cfg.frontend == "audio_stub":
        sch["unembed"] = PSpec((d, v), ("embed", "vocab"),
                               ("normal", 1.0 / np.sqrt(d)))
    blocks = cfg.blocks()
    if _scanned(cfg):
        sch["layers"] = _stack(BLOCK_SCHEMAS[blocks[0]](cfg), cfg.n_layers)
    else:
        sch["blocks"] = [BLOCK_SCHEMAS[k](cfg) for k in blocks]
    return sch


def _init_leaf(ps: PSpec, key, dtype):
    kind = ps.init[0]
    if kind == "normal":
        return (jax.random.normal(key, ps.shape, jnp.float32)
                * ps.init[1]).astype(dtype)
    if kind == "zeros":
        return jnp.zeros(ps.shape, dtype)
    if kind == "ones":
        return jnp.ones(ps.shape, dtype)
    if kind == "const":
        return jnp.full(ps.shape, ps.init[1], dtype)
    raise ValueError(kind)


def init_params(cfg: ModelConfig, seed: int = 0):
    schema = build_schema(cfg)
    leaves, treedef = jax.tree.flatten(schema, is_leaf=_is_pspec)
    keys = jax.random.split(jax.random.PRNGKey(seed), max(len(leaves), 1))
    dtype = DTYPES[cfg.param_dtype]
    vals = [_init_leaf(ps, k, dtype) for ps, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ModelConfig):
    dtype = DTYPES[cfg.param_dtype]
    return jax.tree.map(
        lambda ps: jax.ShapeDtypeStruct(ps.shape, dtype),
        build_schema(cfg), is_leaf=_is_pspec)


def tree_pspecs(schema_tree, rules):
    """Map a PSpec tree -> PartitionSpec tree under the given rules
    (shape-aware: non-divisible dims degrade to replicated)."""
    return jax.tree.map(lambda ps: rules.spec(ps.axes, ps.shape),
                        schema_tree, is_leaf=_is_pspec)


def param_pspecs(cfg: ModelConfig, rules):
    return tree_pspecs(build_schema(cfg), rules)


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def cache_schema(cfg: ModelConfig, batch: int, t_cache: int):
    blocks = cfg.blocks()
    if _scanned(cfg):
        return _stack(_cache_schema_for(blocks[0], cfg, batch, t_cache),
                      cfg.n_layers)
    return [_cache_schema_for(k, cfg, batch, t_cache) for k in blocks]


def _cache_leaf_dtype(cfg: ModelConfig, ps: PSpec):
    # KV entries in compute dtype, recurrent states fp32.
    if ps.init[0] == "zeros" and len(ps.shape) >= 4 and \
            ps.axes[-1] == "head_dim":
        return cfg.compute_dtype()
    return jnp.float32


def init_cache(cfg: ModelConfig, batch: int, t_cache: int):
    def leaf(ps: PSpec):
        if ps.init[0] == "const":
            return jnp.full(ps.shape, ps.init[1], jnp.float32)
        return jnp.zeros(ps.shape, _cache_leaf_dtype(cfg, ps))

    return jax.tree.map(leaf, cache_schema(cfg, batch, t_cache),
                        is_leaf=_is_pspec)


def abstract_cache(cfg: ModelConfig, batch: int, t_cache: int):
    """ShapeDtypeStructs only — NEVER allocates (dry-run caches are TBs)."""
    def leaf(ps: PSpec):
        dt = jnp.float32 if ps.init[0] == "const" \
            else _cache_leaf_dtype(cfg, ps)
        return jax.ShapeDtypeStruct(ps.shape, dt)

    return jax.tree.map(leaf, cache_schema(cfg, batch, t_cache),
                        is_leaf=_is_pspec)


def cache_pspecs(cfg: ModelConfig, batch: int, t_cache: int, rules):
    return tree_pspecs(cache_schema(cfg, batch, t_cache), rules)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def _embed_tokens(params, cfg: ModelConfig, tokens):
    dtype = cfg.compute_dtype()
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    if cfg.emb_scale:
        x = x * np.sqrt(cfg.d_model)
    return x


def _inputs_to_x(params, cfg: ModelConfig, batch):
    """Assemble the layer-0 input from the modality-specific batch dict."""
    dtype = cfg.compute_dtype()
    if cfg.frontend == "audio_stub":
        x = batch["frames"].astype(dtype)
    elif cfg.frontend == "vision_stub":
        x = _embed_tokens(params, cfg, batch["tokens"])
        patches = batch["patch_embeds"].astype(dtype)
        x = jnp.concatenate([patches, x], axis=1)
    else:
        x = _embed_tokens(params, cfg, batch["tokens"])
    return constrain(x, "batch", "seq_res", "act_embed")


def _unembed(params, cfg: ModelConfig, x):
    dtype = cfg.compute_dtype()
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    if "unembed" in params:
        logits = ein("bsd,dv->bsv", x, params["unembed"].astype(dtype),
                     dtype=jnp.float32)
    else:
        logits = ein("bsd,vd->bsv", x, params["embed"].astype(dtype),
                     dtype=jnp.float32)
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits / c)
    return constrain(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Forward (training)
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, batch):
    """-> fp32 logits [B, S, V]."""
    x = _inputs_to_x(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    blocks = cfg.blocks()

    if _scanned(cfg):
        fn = BLOCK_APPLY[blocks[0]]

        def body(xc, lp):
            return fn(lp, xc, cfg, positions=positions), None

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["layers"])
    else:
        for lp, kind in zip(params["blocks"], blocks):
            fn = BLOCK_APPLY[kind]
            if cfg.remat:
                fn = jax.checkpoint(
                    lambda p_, x_, f=BLOCK_APPLY[kind]:
                    f(p_, x_, cfg, positions=positions), prevent_cse=False)
                x = fn(lp, x)
            else:
                x = fn(lp, x, cfg, positions=positions)
    return _unembed(params, cfg, x)


# ---------------------------------------------------------------------------
# Prefill / decode (serving)
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch, cache):
    """Fill the cache from a prompt; -> (last-token logits [B,1,V], cache)."""
    x = _inputs_to_x(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    blocks = cfg.blocks()

    if _scanned(cfg):
        fn = BLOCK_PREFILL[blocks[0]]

        def body(xc, xs):
            lp, lc = xs
            xo, nc = fn(lp, xc, cfg, positions=positions, cache=lc)
            return xo, nc

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    else:
        new_cache = []
        for lp, lc, kind in zip(params["blocks"], cache, blocks):
            x, nc = BLOCK_PREFILL[kind](lp, x, cfg, positions=positions,
                                        cache=lc)
            new_cache.append(nc)
    logits = _unembed(params, cfg, x[:, -1:])
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, tokens, lengths, cache):
    """One token for every sequence. tokens [B,1]; lengths [B] (positions)."""
    x = _embed_tokens(params, cfg, tokens) if cfg.frontend != "audio_stub" \
        else tokens  # encoder-only archs never reach here
    x = constrain(x, "batch", "seq", "act_embed")
    positions = lengths[:, None].astype(jnp.int32)
    blocks = cfg.blocks()

    if _scanned(cfg):
        fn = BLOCK_DECODE[blocks[0]]

        def body(xc, xs):
            lp, lc = xs
            xo, nc = fn(lp, xc, cfg, positions=positions, cache=lc,
                        lengths=lengths)
            return xo, nc

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    else:
        new_cache = []
        for lp, lc, kind in zip(params["blocks"], cache, blocks):
            x, nc = BLOCK_DECODE[kind](lp, x, cfg, positions=positions,
                                       cache=lc, lengths=lengths)
            new_cache.append(nc)
    logits = _unembed(params, cfg, x)
    return logits, new_cache, lengths + 1


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels):
    """Vocab-sharded-safe CE. logits fp32 [B,S,V]; labels [B,S] (-1 = pad)."""
    v = logits.shape[-1]
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), v, dtype=jnp.float32)
    correct = jnp.sum(shifted * onehot, axis=-1) + m[..., 0]
    w = (labels >= 0).astype(jnp.float32)
    nll = (lse - correct) * w
    return jnp.sum(nll) / jnp.maximum(jnp.sum(w), 1.0)


def loss_fn(params, cfg: ModelConfig, batch):
    logits = forward(params, cfg, batch)
    loss = cross_entropy(logits, batch["labels"])
    return loss, {"loss": loss}
