"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Residual block = gated linear recurrence mixer + GeGLU MLP::

    gate = gelu(h @ W_gate)                       # [B,S,R]
    u    = causal_conv1d(h @ W_x)                 # width-4 depthwise
    r_t  = sigmoid(w_r u + b_r);  i_t = sigmoid(w_i u + b_i)
    log a_t = -c * softplus(Lambda) * r_t         # c = 8
    h_t  = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)
    y    = (h_t * gate) @ W_out

Training/prefill uses ``jax.lax.associative_scan`` over the sequence
(parallel-friendly; the Pallas kernel implements a VMEM-tiled variant of the
same recurrence).  Decode is the single-step update with a carried state —
O(1) per token, which is what makes ``long_500k`` tractable for this arch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import PSpec, ein, mlp_apply, mlp_schema, rms_norm

_C = 8.0  # Griffin's recurrence-gate temperature


def rglru_schema(cfg: ModelConfig) -> dict:
    d, r, f, cw = cfg.d_model, cfg.rnn_width, cfg.d_ff, cfg.conv_width
    s = 1.0 / np.sqrt(d)
    return {
        "ln1": PSpec((d,), ("norm",), ("zeros",)),
        "w_gate": PSpec((d, r), ("embed", "rnn"), ("normal", s)),
        "w_x": PSpec((d, r), ("embed", "rnn"), ("normal", s)),
        "conv_w": PSpec((cw, r), ("norm", "rnn"), ("normal", 0.5)),
        "conv_b": PSpec((r,), ("rnn",), ("zeros",)),
        "w_i": PSpec((r,), ("rnn",), ("ones",)),
        "b_i": PSpec((r,), ("rnn",), ("zeros",)),
        "w_r": PSpec((r,), ("rnn",), ("ones",)),
        "b_r": PSpec((r,), ("rnn",), ("zeros",)),
        # softplus(-5) ~= 0.0067 -> a ~= exp(-8*0.0067*sigmoid) in (0.95,1)
        "lam": PSpec((r,), ("rnn",), ("const", -5.0)),
        "w_out": PSpec((r, d), ("rnn", "embed"), ("normal", 1.0 / np.sqrt(r))),
        "ln2": PSpec((d,), ("norm",), ("zeros",)),
        "mlp": mlp_schema(d, f, cfg.activation),
    }


def _causal_conv(u, w, b, prev=None):
    """Depthwise causal conv: out_t = sum_j w[j] * u_{t-(cw-1-j)} + b.

    u: [B,S,R]; w: [cw,R] (tap cw-1 = current step); prev: [B,cw-1,R]
    carries the trailing inputs across prefill/decode steps.
    """
    s = u.shape[1]
    cw = w.shape[0]
    if prev is None:
        full = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        full = jnp.concatenate([prev.astype(u.dtype), u], axis=1)
    acc = None
    for j in range(cw):
        sl = jax.lax.slice_in_dim(full, j, j + s, axis=1)
        term = sl * w[j][None, None, :].astype(u.dtype)
        acc = term if acc is None else acc + term
    return acc + b[None, None, :].astype(u.dtype)


def _gates(p, u):
    uf = u.astype(jnp.float32)
    i = jax.nn.sigmoid(uf * p["w_i"] + p["b_i"])
    r = jax.nn.sigmoid(uf * p["w_r"] + p["b_r"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r      # [B,S,R] fp32
    a = jnp.exp(log_a)
    # sqrt(1-a^2) with log1p for stability near a=1.
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return a, beta * (i * uf)


def _mixer_train(p, h, cfg, conv_prev=None):
    dtype = cfg.compute_dtype()
    gate = jax.nn.gelu(
        ein("bsd,dr->bsr", h, p["w_gate"].astype(dtype), dtype=dtype)
        .astype(jnp.float32), approximate=True).astype(dtype)
    u = ein("bsd,dr->bsr", h, p["w_x"].astype(dtype), dtype=dtype)
    u = constrain(u, "batch", "seq", "rnn")
    uc = _causal_conv(u, p["conv_w"], p["conv_b"], conv_prev)
    a, bterm = _gates(p, uc)

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, hseq = jax.lax.associative_scan(comb, (a, bterm), axis=1)
    hseq = constrain(hseq.astype(dtype), "batch", "seq", "rnn")
    y = ein("bsr,rd->bsd", hseq * gate, p["w_out"].astype(dtype), dtype=dtype)
    state = {"h": hseq[:, -1].astype(jnp.float32),
             "conv": u[:, -(cfg.conv_width - 1):].astype(jnp.float32)}
    return y, state


def rglru_block_apply(p, x, cfg: ModelConfig, **_):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    y, _state = _mixer_train(p, h, cfg)
    x = x + constrain(y, "batch", "seq_res", "act_embed")
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + mlp_apply(p["mlp"], h2, cfg.activation, cfg.compute_dtype())


def rglru_block_prefill(p, x, cfg: ModelConfig, *, cache, **_):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    y, state = _mixer_train(p, h, cfg)
    x = x + constrain(y, "batch", "seq_res", "act_embed")
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + mlp_apply(p["mlp"], h2, cfg.activation, cfg.compute_dtype())
    return x, state


def rglru_block_decode(p, x, cfg: ModelConfig, *, cache, **_):
    """x: [B,1,D]; cache: {"h": [B,R] f32, "conv": [B,cw-1,R] f32}."""
    dtype = cfg.compute_dtype()
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    gate = jax.nn.gelu(
        ein("bsd,dr->bsr", h, p["w_gate"].astype(dtype), dtype=dtype)
        .astype(jnp.float32), approximate=True).astype(dtype)
    u = ein("bsd,dr->bsr", h, p["w_x"].astype(dtype), dtype=dtype)
    uc = _causal_conv(u, p["conv_w"], p["conv_b"], prev=cache["conv"])
    a, bterm = _gates(p, uc)
    hnew = a[:, 0] * cache["h"] + bterm[:, 0]        # [B,R] fp32
    y = ein("bsr,rd->bsd", hnew[:, None].astype(dtype) * gate,
            p["w_out"].astype(dtype), dtype=dtype)
    x = x + y
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + mlp_apply(p["mlp"], h2, cfg.activation, dtype)
    conv_new = jnp.concatenate(
        [cache["conv"][:, 1:], u.astype(jnp.float32)], axis=1)
    return x, {"h": hnew, "conv": conv_new}


def rglru_cache_schema(cfg: ModelConfig, batch: int) -> dict:
    r, cw = cfg.rnn_width, cfg.conv_width
    return {
        "h": PSpec((batch, r), ("cache_batch", "rnn"), ("zeros",)),
        "conv": PSpec((batch, cw - 1, r), ("cache_batch", "norm", "rnn"),
                      ("zeros",)),
    }
