"""Transformer building blocks: norm, RoPE, GQA attention, gated FFN.

All matmuls run in the config compute dtype (bf16) with fp32 accumulation;
softmax and norms run in fp32.  Attention is written flash-style in pure
jnp/lax (blocked over query chunks, online against the full K for the chunk)
so the dry-run memory analysis reflects an O(S·chunk) working set — the
Pallas kernels in :mod:`repro.kernels` are drop-in replacements of the same
math for real TPUs.

Parameter trees are built from a *schema*: a pytree of :class:`PSpec`
(shape, logical axes, init) leaves; the same schema drives initialization,
``jax.eval_shape`` abstract params for the dry-run, and sharding specs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import constrain
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class PSpec:
    """Parameter/cache leaf spec: shape + logical axes + init recipe.

    A plain (unregistered) dataclass so jax.tree treats it as a leaf.
    init: ("normal", scale) | ("zeros",) | ("ones",) | ("const", c)
    """

    shape: tuple
    axes: tuple
    init: tuple = ("normal", 1.0)


def _f32(x):
    return x.astype(jnp.float32)


def ein(eq, *args, dtype):
    """Projection einsum in the compute dtype.

    ``preferred_element_type=dtype`` (not fp32): the MXU accumulates fp32
    *within* a shard regardless, but emitting the requested dtype means
    GSPMD's cross-shard partial-sum all-reduces move bf16, not fp32 —
    iteration 2 of EXPERIMENTS.md §Perf halved most TP collective bytes
    this way.  Loss/logits paths pass dtype=float32 explicitly.
    """
    return jnp.einsum(eq, *args, preferred_element_type=dtype).astype(dtype)


# ---------------------------------------------------------------------------
# Norms & embeddings
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps):
    xf = _f32(x)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + _f32(scale))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta):
    """x: [B, S, H, dh]; positions: [B, S] (absolute)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [B,S,half]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = _f32(x1), _f32(x2)
    # Cast the halves *before* the concat: the concat result is what GSPMD
    # reshards (seq-shard -> head-shard all-to-all); emitting bf16 halves
    # that traffic (EXPERIMENTS.md §Perf iteration 3a).
    out = jnp.concatenate(
        [(x1f * cos - x2f * sin).astype(x.dtype),
         (x2f * cos + x1f * sin).astype(x.dtype)], axis=-1)
    return out


# ---------------------------------------------------------------------------
# Attention (training / prefill: blocked over query chunks)
# ---------------------------------------------------------------------------

def _attend_block(q, k, v, q_start, kv_start, causal, window, lengths, dtype):
    """q: [B,Tq,H,dh] vs k,v: [B,Tk,H,dh] -> [B,Tq,H,dh] (fp32 softmax).

    Head-major einsums keep one 'model'-sharded head dim end-to-end.  The
    earlier grouped formulation (reshape H -> (K, g)) split the sharded head
    axis across two tensor dims and GSPMD fell back to *involuntary full
    rematerialization* in the attention backward — all-gathering fp32 score
    tensors (3 x 128 GiB per layer at llama3-405b/train_4k; EXPERIMENTS.md
    §Perf iteration 1).
    """
    scores = jnp.einsum("bthd,bshd->bhts", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(q.shape[-1])
    tq, tk = q.shape[1], k.shape[1]
    iq = q_start + jnp.arange(tq)[:, None]           # [tq,1]
    jk = kv_start + jnp.arange(tk)[None, :]          # [1,tk]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask = jk <= iq
        if window:
            mask = jnp.logical_and(mask, jk > iq - window)
    if lengths is not None:                          # [B] valid kv lengths
        mask = jnp.logical_and(mask[None], (jk[None] < lengths[:, None, None]))
        mask = mask[:, None]                         # [B,1,tq,tk]
    else:
        mask = mask[None, None]                      # [1,1,tq,tk]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs.astype(dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(dtype)


def _expand_kv(kv, g):
    """[B,T,K,dh] -> [B,T,K*g,dh]: q head h attends kv head h // g.

    Under the 'model'-sharded q-head layout each device materializes only
    its own head slice, so the repeat costs no cross-device communication —
    it exists to hand GSPMD a single clean head axis.
    """
    if g == 1:
        return kv
    return jnp.repeat(kv, g, axis=2)


def attention(q, k, v, *, causal, window=0, q_offset=0, lengths=None,
              q_block=512, dtype=jnp.bfloat16):
    """GQA attention. q: [B,S,H,dh]; k,v: [B,T,K,dh]."""
    b, s, h, dh = q.shape
    g = h // k.shape[2]
    # No explicit constraint on the expanded kv: GSPMD propagates the
    # q-side head sharding into the repeat (a local slice of the
    # replicated K heads); constraining it forced H-sized reshards at
    # prefill (§Perf iteration 4: llama3 prefill 67.1s -> re-measured).
    k = _expand_kv(k, g)
    v = _expand_kv(v, g)
    while s % q_block:
        q_block //= 2

    if s <= q_block:
        return _attend_block(q, k, v, q_offset, 0, causal, window, lengths,
                             dtype)

    nb = s // q_block
    qb = q.reshape(b, nb, q_block, h, dh).transpose(1, 0, 2, 3, 4)

    if causal and window and window < k.shape[1]:
        # Local attention: each q block only sees a K slice of
        # window + q_block positions ending at the block's last query.
        kv_span = window + q_block

        def blk(i, qi):
            q_start = i * q_block
            kv_start = jnp.maximum(q_start + q_block - kv_span, 0)
            ks = jax.lax.dynamic_slice_in_dim(k, kv_start, kv_span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, kv_start, kv_span, axis=1)
            return _attend_block(qi, ks, vs, q_offset + q_start,
                                 kv_start + q_offset, causal, window,
                                 None, dtype)
    else:
        def blk(i, qi):
            q_start = i * q_block
            return _attend_block(qi, k, v, q_offset + q_start, 0, causal,
                                 window, lengths, dtype)

    def body(_, xs):
        i, qi = xs
        return None, blk(i, qi)

    _, outs = jax.lax.scan(body, None, (jnp.arange(nb), qb))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)


def cache_slot_positions(last_pos, t_cache):
    """Absolute position held by each ring slot after writing ``last_pos``.

    Slot s holds the largest p <= last_pos with p == s (mod t_cache);
    slots never written (s > last_pos on a cold cache) come out negative.
    For a non-ring cache (t_cache >= seq) this degenerates to
    positions == slot indices with the unwritten tail negative.
    """
    s = jnp.arange(t_cache)
    return last_pos - jnp.mod(last_pos - s, t_cache)


def decode_attention(q, k_cache, v_cache, valid_mask, *, dtype=jnp.bfloat16):
    """Single-token attention against the cache.

    q: [B,1,H,dh]; caches: [B,T,K,dh]; valid_mask: [B,T] bool (position-
    aware: ring slots holding out-of-window positions are masked by the
    caller).  With a sequence-sharded cache (kv_seq -> 'model') GSPMD turns
    the softmax/out reductions into small psums — split-K decode over TP.
    """
    b, _, h, dh = q.shape
    kheads = k_cache.shape[2]
    g = h // kheads
    qg = q.reshape(b, 1, kheads, g, dh)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k_cache,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(dh)
    scores = jnp.where(valid_mask[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", probs.astype(dtype), v_cache,
                     preferred_element_type=jnp.float32).astype(dtype)
    return out.reshape(b, 1, h, dh)


# ---------------------------------------------------------------------------
# Attention block (params schema + apply)
# ---------------------------------------------------------------------------

def attn_schema(cfg: ModelConfig, *, local: bool) -> dict:
    d, h, k, dh, f = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                      cfg.d_ff)
    s = 1.0 / np.sqrt(d)
    sch = {
        "ln1": PSpec((d,), ("norm",), ("zeros",)),
        "wq": PSpec((d, h, dh), ("embed", "q_heads", "head_dim"), ("normal", s)),
        "wk": PSpec((d, k, dh), ("embed", "kv_heads", "head_dim"), ("normal", s)),
        "wv": PSpec((d, k, dh), ("embed", "kv_heads", "head_dim"), ("normal", s)),
        "wo": PSpec((h, dh, d), ("q_heads", "head_dim", "embed"),
                    ("normal", 1.0 / np.sqrt(h * dh))),
        "ln2": PSpec((d,), ("norm",), ("zeros",)),
    }
    if cfg.qkv_bias:
        sch["bq"] = PSpec((h, dh), ("q_heads", "head_dim"), ("zeros",))
        sch["bk"] = PSpec((k, dh), ("kv_heads", "head_dim"), ("zeros",))
        sch["bv"] = PSpec((k, dh), ("kv_heads", "head_dim"), ("zeros",))
    if cfg.n_experts:
        from repro.models.moe import moe_schema
        sch["moe"] = moe_schema(cfg)
    else:
        sch["mlp"] = mlp_schema(d, f, cfg.activation)
    return sch


def mlp_schema(d, f, activation) -> dict:
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    sch = {"wi": PSpec((d, f), ("embed", "ff"), ("normal", s_in)),
           "wo": PSpec((f, d), ("ff", "embed"), ("normal", s_out))}
    if activation in ("swiglu", "geglu"):
        sch["wg"] = PSpec((d, f), ("embed", "ff"), ("normal", s_in))
    return sch


def mlp_apply(p, x, activation, dtype):
    h = ein("bsd,df->bsf", x, p["wi"].astype(dtype), dtype=dtype)
    if activation in ("swiglu", "geglu"):
        g = ein("bsd,df->bsf", x, p["wg"].astype(dtype), dtype=dtype)
        act = jax.nn.silu if activation == "swiglu" else \
            (lambda t: jax.nn.gelu(t, approximate=True))
        h = act(_f32(g)).astype(dtype) * h
    else:
        h = jax.nn.gelu(_f32(h), approximate=True).astype(dtype)
    h = constrain(h, "batch", "seq", "ff")
    return ein("bsf,fd->bsd", h, p["wo"].astype(dtype), dtype=dtype)


def _qkv(p, x, cfg: ModelConfig, positions, dtype):
    q = ein("bsd,dhk->bshk", x, p["wq"].astype(dtype), dtype=dtype)
    k = ein("bsd,dmk->bsmk", x, p["wk"].astype(dtype), dtype=dtype)
    v = ein("bsd,dmk->bsmk", x, p["wv"].astype(dtype), dtype=dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "q_heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def attn_block_apply(p, x, cfg: ModelConfig, *, local: bool, positions,
                     q_offset=0):
    """Full residual block (train/prefill, no cache). x: [B,S,D]."""
    dtype = cfg.compute_dtype()
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg, positions, dtype)
    window = cfg.local_window if local else 0
    out = attention(q, k, v, causal=cfg.causal, window=window,
                    q_offset=q_offset, q_block=cfg.attn_q_block, dtype=dtype)
    out = ein("bshk,hkd->bsd", out, p["wo"].astype(dtype), dtype=dtype)
    x = x + constrain(out, "batch", "seq_res", "act_embed")
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        from repro.models.moe import moe_apply
        y, _aux = moe_apply(p["moe"], h2, cfg)
    else:
        y = mlp_apply(p["mlp"], h2, cfg.activation, dtype)
    return x + constrain(y, "batch", "seq_res", "act_embed")


def attn_block_prefill(p, x, cfg, *, local, positions, cache):
    """Like apply, but also fills the KV cache; returns (x, cache)."""
    dtype = cfg.compute_dtype()
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg, positions, dtype)
    window = cfg.local_window if local else 0
    t_cache = cache["k"].shape[1]
    s = k.shape[1]
    if s >= t_cache:
        # Keep the trailing window, rolled so position p sits at slot
        # p % t_cache — the ring invariant decode relies on (the next
        # write slot s % t_cache then overwrites the oldest entry).
        knew = jnp.roll(k[:, s - t_cache:], shift=s % t_cache, axis=1)
        vnew = jnp.roll(v[:, s - t_cache:], shift=s % t_cache, axis=1)
        cache = {"k": knew.astype(cache["k"].dtype),
                 "v": vnew.astype(cache["v"].dtype)}
    else:
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=1),
        }
    cache = {kk: constrain(vv, "cache_batch", "kv_seq", "kv_heads",
                           "head_dim") for kk, vv in cache.items()}
    out = attention(q, k, v, causal=cfg.causal, window=window,
                    q_offset=0, q_block=cfg.attn_q_block, dtype=dtype)
    out = ein("bshk,hkd->bsd", out, p["wo"].astype(dtype), dtype=dtype)
    x = x + constrain(out, "batch", "seq_res", "act_embed")
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        from repro.models.moe import moe_apply
        y, _ = moe_apply(p["moe"], h2, cfg)
    else:
        y = mlp_apply(p["mlp"], h2, cfg.activation, dtype)
    return x + constrain(y, "batch", "seq_res", "act_embed"), cache


def attn_block_decode(p, x, cfg, *, local, positions, cache, lengths):
    """One-token step. x: [B,1,D]; cache k/v: [B,T,K,dh] (T may be a ring)."""
    dtype = cfg.compute_dtype()
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg, positions, dtype)
    t_cache = cache["k"].shape[1]
    # Ring-buffer write position: lengths mod cache size (full caches ring).
    slot = (lengths[0] % t_cache).astype(jnp.int32)
    kc = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    kc = constrain(kc, "cache_batch", "kv_seq", "kv_heads", "head_dim")
    vc = constrain(vc, "cache_batch", "kv_seq", "kv_heads", "head_dim")
    # Position-aware validity: which absolute position each slot holds
    # after this write, masked causally and (for local blocks) to the
    # window.  Batch decodes at a shared position (lengths[0]), as
    # documented for the benchmark serve step.
    pos = cache_slot_positions(lengths[0], t_cache)      # [T]
    valid = jnp.logical_and(pos >= 0, pos <= lengths[0])
    if local and cfg.local_window:
        valid = jnp.logical_and(valid,
                                pos > lengths[0] - cfg.local_window)
    valid = jnp.broadcast_to(valid[None], (x.shape[0], t_cache))
    out = decode_attention(q, kc.astype(dtype), vc.astype(dtype), valid,
                           dtype=dtype)
    out = ein("bshk,hkd->bsd", out, p["wo"].astype(dtype), dtype=dtype)
    x = x + out
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        from repro.models.moe import moe_apply
        y, _ = moe_apply(p["moe"], h2, cfg)
    else:
        y = mlp_apply(p["mlp"], h2, cfg.activation, dtype)
    return x + y, {"k": kc, "v": vc}


def attn_cache_schema(cfg: ModelConfig, batch: int, t_cache: int,
                      local: bool) -> dict:
    if local and cfg.local_window:
        t_cache = min(t_cache, cfg.local_window + 1)
    return {
        "k": PSpec((batch, t_cache, cfg.n_kv_heads, cfg.head_dim),
                   ("cache_batch", "kv_seq", "kv_heads", "head_dim"),
                   ("zeros",)),
        "v": PSpec((batch, t_cache, cfg.n_kv_heads, cfg.head_dim),
                   ("cache_batch", "kv_seq", "kv_heads", "head_dim"),
                   ("zeros",)),
    }
