"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM.

mLSTM per head (stabilized exponential gating)::

    log f_t = log sigmoid(f̃_t);  m_t = max(log f_t + m_{t-1}, ĩ_t)
    i' = exp(ĩ_t - m_t);  f' = exp(log f_t + m_{t-1} - m_t)
    C_t = f' C_{t-1} + i' v_t k_tᵀ          (C: [dh, dh] matrix memory)
    n_t = f' n_{t-1} + i' k_t
    h_t = C_t q_t / max(|n_tᵀ q_t|, 1)

sLSTM per head: scalar-gated cell with *recurrent* gate inputs
(R h_{t-1} terms) — genuinely sequential, so both train and decode run a
``lax.scan`` over time (the Pallas kernel implements the chunked-parallel
mLSTM form; this module is the XLA/jnp reference semantics).

Block layout follows the paper: the mLSTM block carries its own SiLU output
gate + down-projection (no separate FFN; config ``d_ff=0``); the sLSTM block
is followed by a GeGLU FFN of projection factor 4/3.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import PSpec, ein, mlp_apply, mlp_schema, rms_norm


def _slstm_ff(cfg: ModelConfig) -> int:
    f = int(round(4 * cfg.d_model / 3))
    return -(-f // 8) * 8


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_schema(cfg: ModelConfig) -> dict:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    s = 1.0 / np.sqrt(d)
    return {
        "ln": PSpec((d,), ("norm",), ("zeros",)),
        "wq": PSpec((d, h, dh), ("embed", "q_heads", "head_dim"), ("normal", s)),
        "wk": PSpec((d, h, dh), ("embed", "q_heads", "head_dim"), ("normal", s)),
        "wv": PSpec((d, h, dh), ("embed", "q_heads", "head_dim"), ("normal", s)),
        "w_i": PSpec((d, h), ("embed", "q_heads"), ("normal", s)),
        "b_i": PSpec((h,), ("q_heads",), ("zeros",)),
        "w_f": PSpec((d, h), ("embed", "q_heads"), ("normal", s)),
        "b_f": PSpec((h,), ("q_heads",), ("const", 3.0)),  # open forget gates
        "w_gate": PSpec((d, h, dh), ("embed", "q_heads", "head_dim"),
                        ("normal", s)),
        "mhn": PSpec((h, dh), ("q_heads", "head_dim"), ("zeros",)),
        "w_down": PSpec((h, dh, d), ("q_heads", "head_dim", "embed"),
                        ("normal", 1.0 / np.sqrt(h * dh))),
    }


def _mlstm_cell(carry, qkvif):
    """One time step. carry: (C [B,H,dh,dh], n [B,H,dh], m [B,H]) fp32."""
    C, n, m = carry
    q, k, v, i_t, f_t = qkvif                      # [B,H,dh], gates [B,H]
    log_f = -jax.nn.softplus(-f_t)                 # log sigmoid
    m_new = jnp.maximum(log_f + m, i_t)
    i_p = jnp.exp(i_t - m_new)[..., None]          # [B,H,1]
    f_p = jnp.exp(log_f + m - m_new)[..., None]
    C = f_p[..., None] * C + i_p[..., None] * (v[..., :, None] *
                                               k[..., None, :])
    n = f_p * n + i_p * k
    num = jnp.einsum("bhij,bhj->bhi", C, q)        # C_t q_t
    den = jnp.abs(jnp.einsum("bhj,bhj->bh", n, q))[..., None]
    h = num / jnp.maximum(den, 1.0)
    return (C, n, m_new), h


def _mlstm_scan(p, x, cfg: ModelConfig, carry=None):
    """x: [B,S,D] -> (h [B,S,H,dh], final carry)."""
    dtype = cfg.compute_dtype()
    b = x.shape[0]
    h_, dh = cfg.n_heads, cfg.head_dim
    q = ein("bsd,dhk->bshk", x, p["wq"].astype(dtype), dtype=jnp.float32)
    k = ein("bsd,dhk->bshk", x, p["wk"].astype(dtype),
            dtype=jnp.float32) / np.sqrt(dh)
    v = ein("bsd,dhk->bshk", x, p["wv"].astype(dtype), dtype=jnp.float32)
    i_t = ein("bsd,dh->bsh", x, p["w_i"].astype(dtype),
              dtype=jnp.float32) + p["b_i"]
    f_t = ein("bsd,dh->bsh", x, p["w_f"].astype(dtype),
              dtype=jnp.float32) + p["b_f"]
    if carry is None:
        carry = (jnp.zeros((b, h_, dh, dh), jnp.float32),
                 jnp.zeros((b, h_, dh), jnp.float32),
                 jnp.full((b, h_), -1e30, jnp.float32))
    xs = tuple(jnp.swapaxes(t, 0, 1) for t in (q, k, v, i_t, f_t))
    carry, hs = jax.lax.scan(_mlstm_cell, carry, xs)
    return jnp.swapaxes(hs, 0, 1).astype(dtype), carry


def _mlstm_block(p, x, cfg: ModelConfig, carry=None):
    dtype = cfg.compute_dtype()
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    mixed, carry = _mlstm_scan(p, h, cfg, carry)
    # Per-head RMS norm then SiLU output gate, then down projection.
    mixed = rms_norm(mixed, p["mhn"], cfg.norm_eps)
    gate = ein("bsd,dhk->bshk", h, p["w_gate"].astype(dtype), dtype=dtype)
    mixed = mixed * jax.nn.silu(gate.astype(jnp.float32)).astype(dtype)
    y = ein("bshk,hkd->bsd", mixed, p["w_down"].astype(dtype), dtype=dtype)
    return x + constrain(y, "batch", "seq_res", "act_embed"), carry


def mlstm_block_apply(p, x, cfg, **_):
    y, _c = _mlstm_block(p, x, cfg)
    return y


def mlstm_block_prefill(p, x, cfg, *, cache, **_):
    y, carry = _mlstm_block(p, x, cfg)
    C, n, m = carry
    return y, {"C": C, "n": n, "m": m}


def mlstm_block_decode(p, x, cfg, *, cache, **_):
    carry = (cache["C"], cache["n"], cache["m"])
    y, carry = _mlstm_block(p, x, cfg, carry)
    C, n, m = carry
    return y, {"C": C, "n": n, "m": m}


def mlstm_cache_schema(cfg: ModelConfig, batch: int) -> dict:
    h, dh = cfg.n_heads, cfg.head_dim
    return {
        "C": PSpec((batch, h, dh, dh),
                   ("cache_batch", "q_heads", "head_dim", "norm"), ("zeros",)),
        "n": PSpec((batch, h, dh), ("cache_batch", "q_heads", "head_dim"),
                   ("zeros",)),
        "m": PSpec((batch, h), ("cache_batch", "q_heads"), ("const", -1e30)),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_schema(cfg: ModelConfig) -> dict:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    s = 1.0 / np.sqrt(d)
    sr = 1.0 / np.sqrt(dh)
    return {
        "ln": PSpec((d,), ("norm",), ("zeros",)),
        "wx": PSpec((d, 4, h, dh), ("embed", "norm", "q_heads", "head_dim"),
                    ("normal", s)),
        "r": PSpec((4, h, dh, dh), ("norm", "q_heads", "head_dim", "norm2"),
                   ("normal", sr)),
        "b": PSpec((4, h, dh), ("norm", "q_heads", "head_dim"), ("zeros",)),
        "w_out": PSpec((h, dh, d), ("q_heads", "head_dim", "embed"),
                       ("normal", 1.0 / np.sqrt(h * dh))),
        "ln2": PSpec((d,), ("norm",), ("zeros",)),
        "mlp": mlp_schema(d, _slstm_ff(cfg), "geglu"),
    }


def _slstm_cell(p_r, carry, xt):
    """xt: [B,4,H,dh] pre-activations from W x_t. carry fp32."""
    c, h, n, m = carry                              # [B,H,dh] x3, m [B,H,dh]
    rec = jnp.einsum("ghij,bhj->bghi", p_r, h)      # [B,4,H,dh]
    z_t, i_t, f_t, o_t = [ (xt + rec)[:, g] for g in range(4) ]
    z = jnp.tanh(z_t)
    o = jax.nn.sigmoid(o_t)
    log_f = -jax.nn.softplus(-f_t)
    m_new = jnp.maximum(log_f + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c = f_p * c + i_p * z
    n = f_p * n + i_p
    h_new = o * (c / jnp.maximum(n, 1.0))
    return (c, h_new, n, m_new), h_new


def _slstm_scan(p, x, cfg: ModelConfig, carry=None):
    dtype = cfg.compute_dtype()
    b = x.shape[0]
    h_, dh = cfg.n_heads, cfg.head_dim
    pre = ein("bsd,dghk->bsghk", x, p["wx"].astype(dtype),
              dtype=jnp.float32) + p["b"]
    if carry is None:
        z = jnp.zeros((b, h_, dh), jnp.float32)
        carry = (z, z, z, jnp.full((b, h_, dh), -1e30, jnp.float32))
    xs = jnp.swapaxes(pre, 0, 1)                    # [S,B,4,H,dh]
    carry, hs = jax.lax.scan(
        lambda cr, xt: _slstm_cell(p["r"].astype(jnp.float32), cr, xt),
        carry, xs)
    return jnp.swapaxes(hs, 0, 1).astype(dtype), carry


def _slstm_block(p, x, cfg: ModelConfig, carry=None):
    dtype = cfg.compute_dtype()
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    mixed, carry = _slstm_scan(p, h, cfg, carry)
    y = ein("bshk,hkd->bsd", mixed, p["w_out"].astype(dtype), dtype=dtype)
    x = x + constrain(y, "batch", "seq_res", "act_embed")
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + mlp_apply(p["mlp"], h2, "geglu", dtype)
    return x, carry


def slstm_block_apply(p, x, cfg, **_):
    y, _c = _slstm_block(p, x, cfg)
    return y


def slstm_block_prefill(p, x, cfg, *, cache, **_):
    y, carry = _slstm_block(p, x, cfg)
    c, h, n, m = carry
    return y, {"c": c, "h": h, "n": n, "m": m}


def slstm_block_decode(p, x, cfg, *, cache, **_):
    carry = (cache["c"], cache["h"], cache["n"], cache["m"])
    y, carry = _slstm_block(p, x, cfg, carry)
    c, h, n, m = carry
    return y, {"c": c, "h": h, "n": n, "m": m}


def slstm_cache_schema(cfg: ModelConfig, batch: int) -> dict:
    h, dh = cfg.n_heads, cfg.head_dim
    vec = ("cache_batch", "q_heads", "head_dim")
    return {
        "c": PSpec((batch, h, dh), vec, ("zeros",)),
        "h": PSpec((batch, h, dh), vec, ("zeros",)),
        "n": PSpec((batch, h, dh), vec, ("zeros",)),
        "m": PSpec((batch, h, dh), vec, ("const", -1e30)),
    }
