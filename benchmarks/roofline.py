import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis per (arch x shape) cell on the single-pod 16x16 mesh.

XLA's cost model counts while-loop bodies ONCE (verified empirically), so the
production compile (scan-over-layers, grad-accum scan, q-block scan)
undercounts FLOPs/bytes/collectives.  This module therefore measures
*unrolled shallow* variants and extrapolates:

    C(L) = a + (L/period) * c        (depth finite-difference)

* layers unrolled at L in {period, 2*period}; attention q-block scan
  unrolled (attn_q_block = seq_len); microbatch loop unrolled.
* train cells add an (L1, M=2-unrolled) compile: per-microbatch *weight*
  re-gathers (FSDP all-gathers are batch-size independent) scale with M,
  activation-proportional collectives do not — measured directly as
  w = coll(L1,M2) - coll(L1,M1).

Conventions (SPMD modules carry per-partition shapes):
* ``flops``/``bytes`` from cost_analysis are **per-device** values;
* collective ``link_bytes`` (repro.dist.hlo_analysis) is per-device link
  traffic with ring factors applied.
Terms (seconds, per device == per step on the critical path):
    compute   = flops / 197e12        (bf16 peak per v5e chip)
    memory    = bytes / 819e9         (closed-form per-device HBM traffic:
                                       analytic_hbm_bytes sharded by the
                                       cell's Rules — weights/TP, cache/
                                       (batch x heads), acts/data; the
                                       HLO bytes-accessed alternative
                                       re-counts fused traffic and is
                                       recorded alongside in the JSON)
    collective= link_bytes / 50e9     (per-link ICI, ring factors applied)

Known caveat (documented in EXPERIMENTS.md): the two recurrent archs keep a
time-step scan in the HLO even in analysis mode; their compute/memory terms
take the analytic model (exact closed forms), collectives are measured
(no collectives inside the time scan).
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.dist.hlo_analysis import (analytic_hbm_bytes,
                                     analytic_model_flops, collective_stats,
                                     xla_cost)
from repro.dist.sharding import build_rules, use_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_specs, decode_specs
from repro.models import lm
from repro.models.config import cell_applicable, standard_shapes
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.step import make_train_step

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "roofline"
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_SCAN_TIME_ARCHS = {"xlstm-125m"}   # time-step scan stays in the HLO


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _analysis_cfg(cfg, n_layers, shape):
    qb = min(shape.seq_len, 32768)
    return dataclasses.replace(
        cfg, n_layers=n_layers, scan_layers=False, attn_q_block=qb)


def _measure(cfg, shape, mesh, *, microbatches=1):
    """Lower+compile one analysis variant; returns per-device metrics."""
    rules = build_rules(mesh, kv_heads=cfg.n_kv_heads,
                        n_experts=cfg.n_experts, step=shape.kind,
                        seq_parallel=cfg.seq_parallel,
                        expert_parallel=cfg.expert_parallel)
    aparams = lm.abstract_params(cfg)
    pspecs = lm.param_pspecs(cfg, rules)
    with use_mesh(mesh, rules):
        if shape.kind == "train":
            opt = AdamW(state_dtype=cfg.opt_state_dtype)
            fn = make_train_step(cfg, opt, cosine_schedule(3e-4, 10, 100),
                                 microbatches=microbatches,
                                 unroll_accum=True)
            aopt = jax.eval_shape(opt.init, aparams)
            ospecs = type(aopt)(m=pspecs, v=pspecs, count=P())
            bspecs, baxes = batch_specs(cfg, shape)
            bshard = {k: rules.spec(baxes[k], bspecs[k].shape) for k in baxes}
            jfn = jax.jit(fn, in_shardings=(
                _ns(mesh, pspecs), _ns(mesh, ospecs),
                NamedSharding(mesh, P()), _ns(mesh, bshard)),
                donate_argnums=(0, 1))
            args = (aparams, aopt, jax.ShapeDtypeStruct((), jax.numpy.int32),
                    bspecs)
        elif shape.kind == "prefill":
            bspecs, baxes = batch_specs(cfg, shape)
            bshard = {k: rules.spec(baxes[k], bspecs[k].shape) for k in baxes}
            acache = lm.abstract_cache(cfg, shape.global_batch,
                                       shape.seq_len)
            cspecs = lm.cache_pspecs(cfg, shape.global_batch, shape.seq_len,
                                     rules)
            jfn = jax.jit(lambda p, b, c: lm.prefill(p, cfg, b, c),
                          in_shardings=(_ns(mesh, pspecs),
                                        _ns(mesh, bshard),
                                        _ns(mesh, cspecs)),
                          donate_argnums=(2,))
            args = (aparams, bspecs, acache)
        else:
            tokens, lengths, acache, _ = decode_specs(cfg, shape)
            cspecs = lm.cache_pspecs(cfg, shape.global_batch, shape.seq_len,
                                     rules)
            jfn = jax.jit(lambda p, t, l, c: lm.decode_step(p, cfg, t, l, c),
                          in_shardings=(
                              _ns(mesh, pspecs),
                              NamedSharding(mesh, rules.spec(
                                  ("batch", "seq"), tokens.shape)),
                              NamedSharding(mesh, rules.spec(
                                  ("batch",), lengths.shape)),
                              _ns(mesh, cspecs)),
                          donate_argnums=(3,))
            args = (aparams, tokens, lengths, acache)
        compiled = jfn.lower(*args).compile()
        cost = xla_cost(compiled)
        coll = collective_stats(compiled.as_text(),
                                int(np.prod(mesh.devices.shape)))
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "link_bytes": coll["total"]["link_bytes"],
            "coll_ops": coll["ops"]}


def analyze_cell(arch: str, shape_name: str, force=False) -> dict:
    cell = f"{arch}__{shape_name}"
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    out_path = ARTIFACTS / f"{cell}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg, meta = registry.get(arch)
    shape = standard_shapes(meta.train_microbatches)[shape_name]
    rec = {"cell": cell, "arch": arch, "shape": shape_name, "ok": False}
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec.update(skipped=True, reason=why, ok=True)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    try:
        t0 = time.time()
        mesh = make_production_mesh(multi_pod=False)
        period = len(cfg.block_pattern)
        l1, l2 = period, 2 * period
        m1 = _measure(_analysis_cfg(cfg, l1, shape), shape, mesh)
        m2 = _measure(_analysis_cfg(cfg, l2, shape), shape, mesh)
        per_layer = {k: (m2[k] - m1[k]) / period for k in
                     ("flops", "bytes", "link_bytes")}
        base = {k: m1[k] - per_layer[k] * period for k in per_layer}
        totals = {k: base[k] + per_layer[k] * cfg.n_layers for k in per_layer}

        micro_w = 0.0
        m_full = shape.microbatches if shape.kind == "train" else 1
        if shape.kind == "train" and m_full > 1:
            mm = _measure(_analysis_cfg(cfg, l1, shape), shape, mesh,
                          microbatches=2)
            # per-microbatch weight re-gather traffic for l1 layers
            micro_w = max(mm["link_bytes"] - m1["link_bytes"], 0.0) / period
            totals["link_bytes"] += micro_w * cfg.n_layers * (m_full - 1)

        model_flops = analytic_model_flops(cfg, shape)   # global
        n_dev = 256
        hlo_flops = totals["flops"]
        if arch in _SCAN_TIME_ARCHS:
            # time-scan body counted once: take the analytic per-device value
            hlo_flops = model_flops / n_dev
        rules = build_rules(mesh, kv_heads=cfg.n_kv_heads,
                            n_experts=cfg.n_experts, step=shape.kind,
                            seq_parallel=cfg.seq_parallel,
                            expert_parallel=cfg.expert_parallel)
        hbm_bytes = analytic_hbm_bytes(cfg, shape, rules)
        t_compute = hlo_flops / PEAK_FLOPS
        t_memory = hbm_bytes / HBM_BW
        t_coll = totals["link_bytes"] / LINK_BW
        dominant = max((t_compute, "compute"), (t_memory, "memory"),
                       (t_coll, "collective"))[1]
        useful = model_flops / max(hlo_flops * n_dev, 1.0)
        rec.update(
            ok=True, analyze_s=round(time.time() - t0, 1),
            per_layer=per_layer, base=base, totals=totals,
            micro_weight_link_bytes=micro_w,
            microbatches=m_full,
            model_flops=model_flops,
            hlo_flops_per_dev=hlo_flops,
            hbm_bytes_per_dev=hbm_bytes,
            hlo_bytes_accessed_per_dev=totals["bytes"],
            t_compute_s=t_compute, t_memory_s=t_memory,
            t_collective_s=t_coll, dominant=dominant,
            useful_ratio=useful,
            roofline_fraction=t_compute / max(t_compute, t_memory, t_coll),
        )
    except Exception as e:
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-1500:])
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else [a.replace("_", "-")
                                           for a in registry.ARCHS]
    shapes = [args.shape] if args.shape else list(standard_shapes())
    fails = 0
    for arch in archs:
        for shape in shapes:
            rec = analyze_cell(arch, shape, force=args.force)
            tag = "SKIP" if rec.get("skipped") else (
                "ok" if rec["ok"] else "FAIL")
            fails += 0 if rec["ok"] else 1
            if rec.get("skipped"):
                print(f"[SKIP] {rec['cell']}", flush=True)
            elif rec["ok"]:
                print(f"[ok  ] {rec['cell']:45s} dom={rec['dominant']:10s} "
                      f"comp={rec['t_compute_s']*1e3:8.2f}ms "
                      f"mem={rec['t_memory_s']*1e3:8.2f}ms "
                      f"coll={rec['t_collective_s']*1e3:8.2f}ms "
                      f"useful={rec['useful_ratio']:.2f}", flush=True)
            else:
                print(f"[FAIL] {rec['cell']}: {rec.get('error')}", flush=True)
    raise SystemExit(1 if fails else 0)


if __name__ == "__main__":
    main()
