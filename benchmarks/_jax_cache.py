"""Shared JAX persistent-compilation-cache setup for the bench drivers."""

from __future__ import annotations

from pathlib import Path


def enable_persistent_cache(cache_dir: Path) -> None:
    """Point the live XLA compile cache at ``cache_dir`` (best-effort:
    the cache is an optimization, never a requirement)."""
    import jax
    try:
        cache_dir.mkdir(parents=True, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", str(cache_dir))
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        # The backend may already be initialized (module-level jnp consts
        # in repro.core.simlock) — re-point the live cache at the dir.
        from jax.experimental.compilation_cache import (
            compilation_cache as cc)
        cc.reset_cache()
    except Exception as e:
        print(f"# persistent compile cache unavailable: {e}")
