"""Simulator performance benchmark — tracks the batched sweep engine.

Measures, per paper figure: total wall-clock, the compile/run split (cold
call vs. hot repeat), simulated events/second, and how many XLA
executables the figure compiled.  For fig1 it additionally times the
*per-cell seed path* — one jit per (policy, n_cores) cell with the seed's
one-event-per-iteration loop (``chunk=1``) — against the batched sweep
(one executable per policy, all thread counts as an active-core mask).

Writes ``BENCH_simlock.json`` at the repo root so the perf trajectory is
tracked from PR to PR (protocol in docs/simulator.md).

    PYTHONPATH=src python -m benchmarks.simperf [--quick] [--figs fig1,...]
"""

from __future__ import annotations

import sys

# Both must precede the first jax import (hence PYTHONPATH=src in every
# invocation): per-op shapes in the simulator are tiny (N<=8 cores), so
# XLA's intra-op threading buys nothing and only thrashes — pinning it
# lets the concurrently-dispatched policy sweeps (and their compiles)
# overlap cleanly on the container's cores.  --devices N virtualizes N
# host-platform devices so the sweeps can shard their cell dimension
# over a data mesh.
from repro.launch.xla_flags import (argv_device_count, ensure_host_devices,
                                    prepend)

prepend("--xla_cpu_multi_thread_eigen=false",
        "intra_op_parallelism_threads=1")
_n = int(argv_device_count(sys.argv, 1))
if _n > 1:
    ensure_host_devices(_n)

import argparse
import dataclasses
import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks._jax_cache import enable_persistent_cache
from repro.core import simlock as sl

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_simlock.json"


def _compiles() -> int:
    return sl.n_batch_executables() + sl._run_single._cache_size()


def _events(st) -> int:
    return int(np.sum(np.asarray(st.events)))


def _hlo_accounting(log_start: int) -> dict:
    """Aggregate the analytic HLO accounting of every sweep executable run
    since ``log_start`` (repro.dist.hlo_analysis via simlock's AOT compile
    records; cache hits included, single-run ``sl.run`` cells excluded)."""
    recs = sl.sweep_log()[log_start:]
    return {
        "sweep_calls": len(recs),
        "flops": sum(r["flops"] for r in recs),
        "bytes_accessed": sum(r["bytes_accessed"] for r in recs),
        "collective_count": sum(r["collectives"]["total_count"]
                                for r in recs),
        "collective_bytes": sum(r["collectives"]["total_bytes"]
                                for r in recs),
        "devices": max((r["devices"] for r in recs), default=1),
    }


def _fig1_policies(quick: bool):
    """Exactly fig1's workload — reuse paper_figs' calibration (every
    registered policy, one executable each) so this benchmark can never
    drift from the figure it claims to track."""
    from benchmarks import paper_figs
    paper_figs.SIM_SCALE = 0.1 if quick else 1.0
    return paper_figs.fig1_policies()


def bench_fig1_batched_vs_seed(quick: bool) -> dict:
    """The acceptance benchmark: fig1's cells (8 thread counts x every
    registered policy), batched vs. per-cell."""
    from concurrent.futures import ThreadPoolExecutor
    from benchmarks import paper_figs
    cfgs = _fig1_policies(quick)
    ns = list(range(1, 9))

    def one_policy(arg):
        _, cfg, slo = arg
        st, _ = sl.sweep(cfg, {"n_cores": ns}, slo_us=slo,
                         mesh=paper_figs.MESH)
        jax.block_until_ready(st.events)
        return _events(st)

    # --- batched sweep engine: one executable per policy, the policies
    # dispatched concurrently (independent executables; XLA releases the
    # GIL, so they overlap on the container's cores).  Concurrency is
    # capped at cores+1: with the registry at 7 policies, 7 concurrent
    # XLA compiles on 2 cores thrash (measured 59s cold vs 43s at 3
    # workers).  The seed path below stays sequential — exactly how the
    # seed ran it.  Mesh-sharded sweeps must NOT overlap in one process:
    # XLA CPU's collective rendezvous interleaves participants from
    # concurrent executables sharing a device set and deadlocks.
    import os
    n_workers = 1 if paper_figs.MESH is not None else \
        min(len(cfgs), (os.cpu_count() or 2) + 1)
    with ThreadPoolExecutor(n_workers) as pool:
        c0 = _compiles()
        h0 = len(sl.sweep_log())
        t0 = time.time()
        events = sum(pool.map(one_policy, cfgs))
        batched_cold = time.time() - t0
        batched_compiles = _compiles() - c0
        hlo = _hlo_accounting(h0)
        t0 = time.time()
        sum(pool.map(one_policy, cfgs))
        batched_hot = time.time() - t0

    # --- per-cell seed path: the pre-batching shape of this benchmark:
    # one executable per (policy, n) cell and one event per loop
    # iteration (chunk=1), exactly as the seed simulator ran it.
    c0 = _compiles()
    t0 = time.time()
    for pol, _, slo in cfgs:
        for n in ns:
            cell = dataclasses.replace(
                paper_figs._cfg(pol, n, **paper_figs.FIG1_KW.get(pol, {})),
                chunk=1)
            jax.block_until_ready(sl.run(cell, slo).events)
    seed_wall = time.time() - t0
    seed_compiles = _compiles() - c0

    return {
        "cells": len(cfgs) * len(ns),
        "policies": len(cfgs),
        "events": events,
        "batched_wall_s": round(batched_cold, 2),
        "batched_hot_s": round(batched_hot, 2),
        "batched_compile_s_est": round(batched_cold - batched_hot, 2),
        "batched_compilations": batched_compiles,
        "batched_events_per_s": round(events / batched_hot),
        "seed_path_wall_s": round(seed_wall, 2),
        "seed_path_compilations": seed_compiles,
        "speedup_vs_seed_path": round(seed_wall / batched_cold, 2),
        "hlo": hlo,
    }


def bench_figures(quick: bool, figs=None) -> dict:
    """Wall-clock + events/s for every paper figure on the new API."""
    from benchmarks import paper_figs
    paper_figs.SIM_SCALE = 0.1 if quick else 1.0
    out = {}
    for name, fn in paper_figs.ALL.items():
        if figs and name not in figs:
            continue
        c0 = _compiles()
        h0 = len(sl.sweep_log())
        t0 = time.time()
        rows = fn()
        wall = time.time() - t0
        events = sum(r["summary"]["events"] for r in rows if "summary" in r)
        out[name] = {
            "rows": len(rows),
            "wall_s": round(wall, 2),
            "compilations": _compiles() - c0,
            "events": events,
            "hlo": _hlo_accounting(h0),
        }
        if events:
            out[name]["events_per_s"] = round(events / max(wall, 1e-9))
        else:
            # Host-bound figures (bench2/3/5) emit derived aggregate rows
            # with no raw per-cell summaries: a device events/s would be
            # meaningless, so record host row throughput instead.
            # benchmarks/report.py renders either shape.
            out[name]["rows_per_s"] = round(len(rows) / max(wall, 1e-9), 2)
        rate = (f"ev/s={out[name]['events_per_s']}" if events else
                f"rows/s={out[name]['rows_per_s']}")
        print(f"{name:22s} rows={len(rows):3d} wall={wall:7.2f}s "
              f"compiles={out[name]['compilations']} {rate} "
              f"coll={out[name]['hlo']['collective_count']}", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="0.1x sim horizons (CI smoke)")
    ap.add_argument("--figs", type=str, default=None,
                    help="comma-separated figure subset")
    ap.add_argument("--skip-figures", action="store_true",
                    help="only the fig1 batched-vs-seed acceptance bench")
    ap.add_argument("--cache", action="store_true",
                    help="enable the persistent XLA compile cache (OFF by "
                         "default here: compile-cost measurements must be "
                         "cache-cold to stay comparable across runs)")
    ap.add_argument("--devices", type=int, default=1,
                    help="virtualize N host devices and shard every sweep's "
                         "cell dimension over a 1-D data mesh (multi-device "
                         "path; collective accounting goes nonzero)")
    args = ap.parse_args()
    if args.cache:
        enable_persistent_cache(ROOT / "artifacts" / "xla_cache")
    if args.devices > 1:
        from benchmarks import paper_figs
        from repro.launch.mesh import make_sweep_mesh
        paper_figs.MESH = make_sweep_mesh(args.devices)

    figs = set(args.figs.split(",")) if args.figs else None
    rec = {
        "bench": "simlock",
        "host": platform.machine(),
        "jax": jax.__version__,
        "quick": bool(args.quick),
        "chunk": sl.SimConfig().chunk,
        "devices": args.devices,
    }
    print("== fig1: batched sweep vs per-cell seed path ==", flush=True)
    rec["fig1_sweep"] = bench_fig1_batched_vs_seed(args.quick)
    for k, v in rec["fig1_sweep"].items():
        print(f"  {k}: {v}")
    if not args.skip_figures:
        print("== per-figure wall clock ==", flush=True)
        rec["figures"] = bench_figures(args.quick, figs)
        if figs and OUT.exists():
            # A subset recording must not drop the other figures'
            # committed entries: merge into the existing protocol file.
            try:
                prev = json.loads(OUT.read_text()).get("figures", {})
            except ValueError:
                prev = {}
            prev.update(rec["figures"])
            rec["figures"] = prev

    OUT.write_text(json.dumps(rec, indent=1))
    print(f"# wrote {OUT}")


if __name__ == "__main__":
    main()
