"""Paper figure/table reproductions on the JAX discrete-event AMP simulator.

Calibration (documented in EXPERIMENTS.md §Paper-validation): 4 big + 4
little cores (Apple M1 topology); critical sections 3.75x slower on little
cores (the Sysbench gap), non-critical NOP work 1.8x slower (the NOP gap);
CS = 3us on a big core (contended 4-cache-line RMW), intra-epoch noncrit
1us, inter-epoch 5us — chosen so 4 big cores already saturate the lock,
the regime of paper Figures 1/4.  All numbers are simulated microseconds.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import simlock as sl

BIG_SPEED = 1.0
CS_RATIO = 3.75
NC_RATIO = 1.8


def _cfg(policy, n_cores=8, **kw):
    n_big = min(n_cores, 4)
    big = tuple([1] * n_big + [0] * (n_cores - n_big))
    base = dict(
        policy=policy, n_cores=n_cores, big=big,
        speed_cs=tuple(1.0 if b else CS_RATIO for b in big),
        speed_nc=tuple(1.0 if b else NC_RATIO for b in big),
        seg_noncrit_us=(1.0,), seg_cs_us=(3.0,), seg_lock=(0,),
        inter_epoch_us=5.0, sim_time_us=60_000.0)
    base.update(kw)
    return sl.SimConfig(**base)


def _row(name, cfg, slo=1e9, seed=0, windows0=None):
    st = sl.run(cfg, slo, seed, windows0)
    s = sl.summarize(cfg, st)
    return dict(name=name, policy=cfg.policy,
                tput=s["throughput_cs_per_s"],
                p99_all=s["cs_p99_all_us"], ep_p99_all=s["ep_p99_all_us"],
                ep_p99_big=s["ep_p99_big_us"],
                ep_p99_little=s["ep_p99_little_us"], summary=s)


# ---------------------------------------------------------------------------
# Figure 1: throughput/latency collapse scaling 1..8 threads
# (TAS shows little-core-affinity in this regime)
# ---------------------------------------------------------------------------

def fig1_collapse():
    rows = []
    for n in range(1, 9):
        for pol, kw in (("fifo", {}), ("tas", dict(w_big=0.15)),
                        ("prop", {})):
            cfg = _cfg(pol, n_cores=n, **kw)
            r = _row(f"fig1/{pol}/n{n}", cfg)
            r.update(n_threads=n)
            rows.append(r)
    return rows


# ---------------------------------------------------------------------------
# Figure 4: the big-core-affinity TAS scenario (64-line CS analogue)
# ---------------------------------------------------------------------------

def fig4_big_affinity():
    rows = []
    for n in range(1, 9):
        for pol, kw in (("fifo", {}), ("tas", dict(w_big=8.0))):
            cfg = _cfg(pol, n_cores=n, seg_cs_us=(6.0,), **kw)
            r = _row(f"fig4/{pol}/n{n}", cfg)
            r.update(n_threads=n)
            rows.append(r)
    return rows


# ---------------------------------------------------------------------------
# Figure 5: static proportional trade-off
# ---------------------------------------------------------------------------

def fig5_proportional():
    rows = []
    for n in (1, 2, 5, 10, 20, 50):
        cfg = _cfg("prop", prop_n=n)
        r = _row(f"fig5/prop{n}", cfg)
        r.update(proportion=n)
        rows.append(r)
    return rows


# ---------------------------------------------------------------------------
# Bench-1 (Fig 8a/8b): contended epochs, 4 CS over 2 locks; SLO sweep
# ---------------------------------------------------------------------------

def _bench1_cfg(policy, **kw):
    base = dict(seg_noncrit_us=(1.0, 0.5, 0.5, 0.5),
                seg_cs_us=(2.0, 1.0, 3.0, 0.5),
                seg_lock=(0, 1, 0, 1), n_locks=2,
                inter_epoch_us=7.5)
    base.update(kw)
    return _cfg(policy, **base)


def bench1_contended():
    rows = [
        _row("bench1/mcs", _bench1_cfg("fifo")),
        _row("bench1/tas-big", _bench1_cfg("tas", w_big=8.0)),
        _row("bench1/shfl-pb10", _bench1_cfg("prop", prop_n=10)),
    ]
    fifo_p99 = rows[0]["ep_p99_all"]
    for slo in (0.0, fifo_p99, 1.5 * fifo_p99, 2.5 * fifo_p99, 5 * fifo_p99,
                1e5):
        tag = "MAX" if slo >= 1e5 else f"{slo:.0f}"
        # LibASL-MAX = the maximum reorder window directly (paper §4),
        # not AIMD-grown from the default.
        kw = dict(default_window_us=1e5) if slo >= 1e5 else {}
        r = _row(f"bench1/libasl-{tag}", _bench1_cfg("libasl", **kw),
                 slo=slo)
        r.update(slo_us=slo)
        rows.append(r)
    return rows


def bench1_slo_sweep():
    """Figure 8b: one vmap over the SLO axis."""
    cfg = _bench1_cfg("libasl")
    slos = np.linspace(20.0, 400.0, 14)
    st = sl.sweep_slo(cfg, slos)
    rows = []
    for i, slo in enumerate(slos):
        s = sl.summarize(cfg, jax.tree.map(lambda x: x[i], st))
        rows.append(dict(name=f"bench1_sweep/slo{slo:.0f}", slo_us=float(slo),
                         tput=s["throughput_cs_per_s"],
                         ep_p99_little=s["ep_p99_little_us"],
                         ep_p99_big=s["ep_p99_big_us"]))
    return rows


# ---------------------------------------------------------------------------
# Bench-2 (Fig 8d): workload shifts; window adapts across phases
# ---------------------------------------------------------------------------

def bench2_variable(slo=150.0):
    """Paper Fig 8d: the AIMD window re-converges across load shifts; the
    final phase is deliberately impossible (epoch >> SLO) — LibASL must
    fall back to FIFO there (windows collapse), exactly as in the paper."""
    phases = [
        ("base", dict(), True),
        ("x8", dict(seg_noncrit_us=(8.0, 4.0, 4.0, 4.0)), True),
        ("back", dict(), True),
        ("x256", dict(seg_noncrit_us=(256.0, 128.0, 128.0, 128.0)), False),
    ]
    rows = []
    windows = None
    for tag, kw, achievable in phases:
        cfg = _bench1_cfg("libasl", sim_time_us=40_000.0, **kw)
        st = sl.run(cfg, slo, 0, windows)
        windows = st.window
        s = sl.summarize(cfg, st)
        rows.append(dict(
            name=f"bench2/{tag}", slo_us=slo, achievable=achievable,
            tput=s["throughput_cs_per_s"],
            ep_p99_little=s["ep_p99_little_us"],
            mean_window_us=float(np.mean(np.asarray(windows)[4:]) / sl.US),
            violation_excess=max(
                0.0, (s["ep_p99_little_us"] - slo) / max(slo, 1e-9))))
    return rows


# ---------------------------------------------------------------------------
# Bench-3 (Fig 8c): mixed short/long epochs at different ratios
# ---------------------------------------------------------------------------

def bench3_mixed(slo=400.0):
    rows = []
    for short_pct in (0, 20, 40, 60, 80, 100):
        p_long = 1.0 - short_pct / 100.0
        cfg = _bench1_cfg("libasl", long_epoch_prob=p_long,
                          long_epoch_scale=100.0, sim_time_us=120_000.0)
        mcs = _bench1_cfg("fifo", long_epoch_prob=p_long,
                          long_epoch_scale=100.0, sim_time_us=120_000.0)
        r = _row(f"bench3/short{short_pct}", cfg, slo=slo)
        m = _row(f"bench3/mcs{short_pct}", mcs)
        rows.append(dict(name=r["name"], slo_us=slo, short_pct=short_pct,
                         tput=r["tput"], tput_vs_mcs=r["tput"] / m["tput"],
                         ep_p99_little=r["ep_p99_little"]))
    return rows


# ---------------------------------------------------------------------------
# Bench-4 (Fig 8e/8f): scalability at fixed SLOs
# ---------------------------------------------------------------------------

def bench4_scalability():
    # High contention (queue never drains), the paper's Fig 8e regime:
    # LibASL-MAX keeps the lock on big cores and its throughput curve
    # stays flat as little threads join.
    kw = dict(seg_cs_us=(6.0,), seg_noncrit_us=(0.5,), inter_epoch_us=2.0)
    rows = []
    for n in range(1, 9):
        fifo = _row(f"bench4/mcs/n{n}", _cfg("fifo", n_cores=n, **kw))
        tas = _row(f"bench4/tas/n{n}", _cfg("tas", n_cores=n, w_big=8.0,
                                            **kw))
        rows += [dict(fifo, n_threads=n), dict(tas, n_threads=n)]
        for slo, tag in ((0.0, "0"), (tas["ep_p99_all"], "tas-lat"),
                         (1e5, "MAX")):
            wkw = dict(default_window_us=1e5) if slo >= 1e5 else {}
            r = _row(f"bench4/libasl-{tag}/n{n}",
                     _cfg("libasl", n_cores=n, **kw, **wkw), slo=slo)
            r.update(n_threads=n, slo_us=slo)
            rows.append(r)
    return rows


# ---------------------------------------------------------------------------
# Bench-5 (Fig 8g): contention sweep — little cores help at low contention
# ---------------------------------------------------------------------------

def bench5_contention():
    rows = []
    for i, nc in enumerate((0.5, 1, 2, 4, 8, 16, 32, 64, 128)):
        kw = dict(seg_noncrit_us=(float(nc),), seg_cs_us=(2.0,),
                  inter_epoch_us=0.5)
        mcs8 = _row(f"bench5/mcs8/nc{nc}", _cfg("fifo", **kw))
        mcs4 = _row(f"bench5/mcs4/nc{nc}",
                    _cfg("fifo", n_cores=4, **kw))
        tas = _row(f"bench5/tas/nc{nc}", _cfg("tas", w_big=8.0, **kw))
        asl = _row(f"bench5/libasl/nc{nc}",
                   _cfg("libasl", default_window_us=1e5, **kw), slo=1e9)
        rows.append(dict(name=f"bench5/nc{nc}", noncrit_us=nc,
                         tput_libasl=asl["tput"], tput_mcs8=mcs8["tput"],
                         tput_mcs4=mcs4["tput"], tput_tas=tas["tput"],
                         speedup_vs_mcs8=asl["tput"] / mcs8["tput"],
                         speedup_vs_mcs4=asl["tput"] / mcs4["tput"]))
    return rows


# ---------------------------------------------------------------------------
# Bench-6: blocking locks / oversubscription — wakeup latency on the
# FIFO handoff path; LibASL standbys dodge it
# ---------------------------------------------------------------------------

def bench6_blocking():
    """Blocking locks: FIFO handoff pays the parked-waiter wakeup latency on
    *every* transfer; LibASL standby grabs (busy-poll during the window)
    dodge it.  The simulator models the wakeup cost, not the full OS
    scheduler, so this shows the degradation *trend* rather than the
    paper's 96% pthread-vs-MCS gap (limitation noted in EXPERIMENTS.md)."""
    rows = []
    for wakeup in (0.0, 8.0, 20.0):
        for pol, name in (("fifo", "mcs-park"), ("libasl", "libasl-block")):
            cfg = _bench1_cfg(pol, wakeup_us=wakeup)
            r = _row(f"bench6/{name}/w{wakeup:.0f}", cfg,
                     slo=1e5 if pol == "libasl" else 1e9)
            r.update(wakeup_us=wakeup)
            rows.append(r)
    return rows


ALL = {
    "fig1_collapse": fig1_collapse,
    "fig4_big_affinity": fig4_big_affinity,
    "fig5_proportional": fig5_proportional,
    "bench1_contended": bench1_contended,
    "bench1_slo_sweep": bench1_slo_sweep,
    "bench2_variable": bench2_variable,
    "bench3_mixed": bench3_mixed,
    "bench4_scalability": bench4_scalability,
    "bench5_contention": bench5_contention,
    "bench6_blocking": bench6_blocking,
}
