"""Paper figure/table reproductions on the JAX discrete-event AMP simulator.

Calibration (documented in EXPERIMENTS.md §Paper-validation): 4 big + 4
little cores (Apple M1 topology); critical sections 3.75x slower on little
cores (the Sysbench gap), non-critical NOP work 1.8x slower (the NOP gap);
CS = 3us on a big core (contended 4-cache-line RMW), intra-epoch noncrit
1us, inter-epoch 5us — chosen so 4 big cores already saturate the lock,
the regime of paper Figures 1/4.  All numbers are simulated microseconds.

Every figure is expressed on the batched sweep engine
(``simlock.sweep``): one vmapped+jitted call per (policy, program), with
thread counts, SLOs, policy weights, mix ratios and wakeup costs riding as
traced batch axes — fig1's 24 cells compile exactly 3 executables (one per
policy).  ``SIM_SCALE`` shortens every simulation for CI smoke runs
(``benchmarks/run.py --quick``).
"""

from __future__ import annotations

import numpy as np

from repro.core import energy
from repro.core import simlock as sl

BIG_SPEED = 1.0
CS_RATIO = 3.75
NC_RATIO = 1.8

# Global sim-length scale: benchmarks/run.py --quick sets this < 1 so a
# smoke run of every figure fits in CI time.
SIM_SCALE = 1.0

# Optional jax.sharding.Mesh: when set (benchmarks/simperf.py --devices N),
# every figure's sweep shards its cell dimension over MESH's DATA_AXIS —
# results are bit-identical to the unsharded run (tests/test_sweep.py).
MESH = None
DATA_AXIS = "data"


def _cfg(policy, n_cores=8, sim_time_us=60_000.0, **kw):
    n_big = min(n_cores, 4)
    big = tuple([1] * n_big + [0] * (n_cores - n_big))
    base = dict(
        policy=policy, n_cores=n_cores, big=big,
        speed_cs=tuple(1.0 if b else CS_RATIO for b in big),
        speed_nc=tuple(1.0 if b else NC_RATIO for b in big),
        seg_noncrit_us=(1.0,), seg_cs_us=(3.0,), seg_lock=(0,),
        inter_epoch_us=5.0, sim_time_us=sim_time_us * SIM_SCALE)
    base.update(kw)
    return sl.SimConfig(**base)


def _rowdict(name, cfg, s):
    return dict(name=name, policy=cfg.policy,
                tput=s["throughput_cs_per_s"],
                p99_all=s["cs_p99_all_us"], ep_p99_all=s["ep_p99_all_us"],
                ep_p99_big=s["ep_p99_big_us"],
                ep_p99_little=s["ep_p99_little_us"], summary=s)


def _row(name, cfg, slo=1e9, seed=0, windows0=None):
    """Single-cell fallback (bench2's sequential window-carry phases)."""
    st = sl.run(cfg, slo, seed, windows0)
    return _rowdict(name, cfg, sl.summarize(cfg, st))


def _sweep_rows(cfg, axes, namer, *, slo_us=1e9, product=True, extra=None):
    """One batched call -> one row per cell (name via ``namer(cell)``)."""
    st, grid = sl.sweep(cfg, axes, slo_us=slo_us, product=product,
                        mesh=MESH, data_axis=DATA_AXIS)
    rows = []
    for s in sl.sweep_summaries(cfg, st, grid, slo_us=slo_us):
        cell = {k: s[k] for k in grid}
        r = _rowdict(namer(cell), cfg, s)
        r.update({k: v for k, v in cell.items()
                  if not isinstance(v, tuple)})
        if extra:
            r.update(extra(cell, s))
        rows.append(r)
    return rows


# ---------------------------------------------------------------------------
# Figure 1: throughput/latency collapse scaling 1..8 threads
# (TAS shows little-core-affinity in this regime)
# Registry-driven: every policy in repro.core.policies gets a curve — 48
# cells, one compilation per policy (the n axis is an active-core mask;
# w_big and the per-policy knobs ride traced).
# ---------------------------------------------------------------------------

# Per-policy fig1 calibration: non-default knobs + the SLO the policy
# tracks (1e9 = pure-throughput mode).  Policies absent here run with
# defaults, so a newly registered policy appears in fig1 automatically.
FIG1_KW = {"tas": dict(w_big=0.15)}
FIG1_SLO = {"libasl": 1e9, "edf": 100.0}


def fig1_policies():
    """The fig1 workload per registered policy — also the acceptance
    benchmark's grid (benchmarks/simperf reuses this, so the perf
    protocol can never drift from the figure it tracks)."""
    from repro.core.policies import REGISTRY
    return [(name, _cfg(name, 8, **FIG1_KW.get(name, {})),
             FIG1_SLO.get(name, 1e9)) for name in REGISTRY]


def fig1_collapse():
    rows = []
    for pol, cfg, slo in fig1_policies():
        rows += _sweep_rows(
            cfg, {"n_cores": list(range(1, 9))},
            lambda c, p=pol: f"fig1/{p}/n{c['n_cores']}",
            slo_us=slo,
            extra=lambda c, s: dict(n_threads=int(c["n_cores"])))
    return rows


# ---------------------------------------------------------------------------
# Figure 4: the big-core-affinity TAS scenario (64-line CS analogue)
# ---------------------------------------------------------------------------

def fig4_big_affinity():
    rows = []
    for pol, kw in (("fifo", {}), ("tas", dict(w_big=8.0))):
        rows += _sweep_rows(
            _cfg(pol, 8, seg_cs_us=(6.0,), **kw),
            {"n_cores": list(range(1, 9))},
            lambda c, p=pol: f"fig4/{p}/n{c['n_cores']}",
            extra=lambda c, s: dict(n_threads=int(c["n_cores"])))
    return rows


# ---------------------------------------------------------------------------
# Figure 5: static proportional trade-off (prop_n is a traced batch axis)
# ---------------------------------------------------------------------------

def fig5_proportional():
    return _sweep_rows(
        _cfg("prop"), {"prop_n": [1, 2, 5, 10, 20, 50]},
        lambda c: f"fig5/prop{c['prop_n']}",
        extra=lambda c, s: dict(proportion=int(c["prop_n"])))


# ---------------------------------------------------------------------------
# Bench-1 (Fig 8a/8b): contended epochs, 4 CS over 2 locks; SLO sweep
# ---------------------------------------------------------------------------

def _bench1_cfg(policy, **kw):
    base = dict(seg_noncrit_us=(1.0, 0.5, 0.5, 0.5),
                seg_cs_us=(2.0, 1.0, 3.0, 0.5),
                seg_lock=(0, 1, 0, 1), n_locks=2,
                inter_epoch_us=7.5)
    base.update(kw)
    return _cfg(policy, **base)


def bench1_contended():
    # Both phases run the SAME merged 4-policy executable (identical
    # axis names/order and cell count -> one AOT cache entry): phase 1
    # covers the three baseline singles (plus three pad lanes, dropped),
    # phase 2 the libasl SLO column whose values need phase 1's fifo
    # p99.  One compilation for the whole figure, down from 4.
    cfg = _bench1_cfg("fifo", policy_set=("fifo", "tas", "prop",
                                          "libasl"))
    w0 = cfg.default_window_us
    label = {"fifo": "bench1/mcs", "tas": "bench1/tas-big",
             "prop": "bench1/shfl-pb10"}

    def phase(policy, w_big, slos, win0, namer):
        axes = {"policy": list(policy), "w_big": list(w_big),
                "slo_us": list(slos), "window0_us": list(win0)}
        return _sweep_rows(cfg, axes, namer, product=False)

    # Cells 3..5 are pad lanes (fifo duplicates) sliced off below.
    rows = phase(["fifo", "tas", "prop", "fifo", "fifo", "fifo"],
                 [1.0, 8.0, 1.0, 1.0, 1.0, 1.0],
                 [1e9] * 6, [w0] * 6,
                 lambda c: label[c["policy"]])[:3]
    fifo_p99 = rows[0]["ep_p99_all"]
    slos = [0.0, fifo_p99, 1.5 * fifo_p99, 2.5 * fifo_p99, 5 * fifo_p99,
            1e5]
    # LibASL-MAX = the maximum reorder window directly (paper §4), not
    # AIMD-grown from the default: the window0 axis is zipped with the SLO.
    win0 = [w0] * 5 + [1e5]

    def tag(c):
        t = "MAX" if c["slo_us"] >= 1e5 else f"{c['slo_us']:.0f}"
        return f"bench1/libasl-{t}"

    rows += phase(["libasl"] * 6, [1.0] * 6, slos, win0, tag)
    return rows


def bench1_slo_sweep():
    """Figure 8b: the whole SLO axis is one batched call."""
    cfg = _bench1_cfg("libasl")
    slos = list(np.linspace(20.0, 400.0, 14))
    return _sweep_rows(
        cfg, {"slo_us": slos},
        lambda c: f"bench1_sweep/slo{c['slo_us']:.0f}",
        extra=lambda c, s: dict(
            tput=s["throughput_cs_per_s"],
            ep_p99_little=s["ep_p99_little_us"],
            ep_p99_big=s["ep_p99_big_us"]))


# ---------------------------------------------------------------------------
# Bench-2 (Fig 8d): workload shifts; window adapts across phases
# ---------------------------------------------------------------------------

def bench2_variable(slo=150.0):
    """Paper Fig 8d: the AIMD window re-converges across load shifts; the
    final phase is deliberately impossible (epoch >> SLO) — LibASL must
    fall back to FIFO there (windows collapse), exactly as in the paper.
    Sequential by nature (the window state carries across phases; the
    donated ``windows0`` buffer makes each resume copy-free)."""
    phases = [
        ("base", dict(), True),
        ("x8", dict(seg_noncrit_us=(8.0, 4.0, 4.0, 4.0)), True),
        ("back", dict(), True),
        ("x256", dict(seg_noncrit_us=(256.0, 128.0, 128.0, 128.0)), False),
    ]
    rows = []
    windows = None
    for tag, kw, achievable in phases:
        cfg = _bench1_cfg("libasl", sim_time_us=40_000.0, **kw)
        st = sl.run(cfg, slo, 0, windows)
        windows = st.window
        s = sl.summarize(cfg, st)
        rows.append(dict(
            name=f"bench2/{tag}", slo_us=slo, achievable=achievable,
            tput=s["throughput_cs_per_s"],
            ep_p99_little=s["ep_p99_little_us"],
            mean_window_us=float(np.mean(np.asarray(windows)[4:]) / sl.US),
            violation_excess=max(
                0.0, (s["ep_p99_little_us"] - slo) / max(slo, 1e-9))))
    return rows


# ---------------------------------------------------------------------------
# Bench-3 (Fig 8c): mixed short/long epochs at different ratios
# (the mix probability is a traced batch axis: one call per policy)
# ---------------------------------------------------------------------------

def bench3_mixed(slo=400.0):
    short_pcts = (0, 20, 40, 60, 80, 100)
    probs = [1.0 - p / 100.0 for p in short_pcts]
    kw = dict(long_epoch_prob=1.0, long_epoch_scale=100.0,
              sim_time_us=120_000.0)
    asl = _sweep_rows(_bench1_cfg("libasl", **kw),
                      {"long_epoch_prob": probs},
                      lambda c: f"bench3/p{c['long_epoch_prob']:.1f}",
                      slo_us=slo)
    mcs = _sweep_rows(_bench1_cfg("fifo", **kw),
                      {"long_epoch_prob": probs},
                      lambda c: f"bench3/mcs{c['long_epoch_prob']:.1f}")
    rows = []
    for pct, r, m in zip(short_pcts, asl, mcs):
        rows.append(dict(name=f"bench3/short{pct}", slo_us=slo,
                         short_pct=pct, tput=r["tput"],
                         tput_vs_mcs=r["tput"] / m["tput"],
                         ep_p99_little=r["ep_p99_little"]))
    return rows


# ---------------------------------------------------------------------------
# Bench-4 (Fig 8e/8f): scalability at fixed SLOs
# ---------------------------------------------------------------------------

def bench4_scalability():
    # High contention (queue never drains), the paper's Fig 8e regime:
    # LibASL-MAX keeps the lock on big cores and its throughput curve
    # stays flat as little threads join.
    kw = dict(seg_cs_us=(6.0,), seg_noncrit_us=(0.5,), inter_epoch_us=2.0)
    ns = list(range(1, 9))
    fifo = _sweep_rows(_cfg("fifo", **kw), {"n_cores": ns},
                       lambda c: f"bench4/mcs/n{c['n_cores']}",
                       extra=lambda c, s: dict(n_threads=int(c["n_cores"])))
    tas = _sweep_rows(_cfg("tas", w_big=8.0, **kw), {"n_cores": ns},
                      lambda c: f"bench4/tas/n{c['n_cores']}",
                      extra=lambda c, s: dict(n_threads=int(c["n_cores"])))
    rows = []
    for f, t in zip(fifo, tas):
        rows += [f, t]

    # LibASL at 3 SLO points per n — one zipped 24-cell call (slo and
    # window0 pair with each n; "tas-lat" tracks the measured TAS P99).
    asl_cfg = _cfg("libasl", **kw)
    w_dflt = asl_cfg.default_window_us
    n_ax, slo_ax, win_ax, tags = [], [], [], []
    for t in tas:
        n = t["n_threads"]
        for slo, tag, w0 in ((0.0, "0", w_dflt),
                             (t["ep_p99_all"], "tas-lat", w_dflt),
                             (1e5, "MAX", 1e5)):
            n_ax.append(n)
            slo_ax.append(slo)
            win_ax.append(w0)
            tags.append(f"bench4/libasl-{tag}/n{n}")
    tag_of = {(n, s): tg for n, s, tg in zip(n_ax, slo_ax, tags)}
    rows += _sweep_rows(
        asl_cfg,
        {"n_cores": n_ax, "slo_us": slo_ax, "window0_us": win_ax},
        lambda c: tag_of[(int(c["n_cores"]), float(c["slo_us"]))],
        product=False,
        extra=lambda c, s: dict(n_threads=int(c["n_cores"])))
    return rows


# ---------------------------------------------------------------------------
# Bench-5 (Fig 8g): contention sweep — little cores help at low contention
# (the noncrit duration is a table batch axis: 3 calls for 27 cells)
# ---------------------------------------------------------------------------

def bench5_contention():
    ncs = (0.5, 1, 2, 4, 8, 16, 32, 64, 128)
    nc_ax = [(float(nc),) for nc in ncs]
    kw = dict(seg_cs_us=(2.0,), inter_epoch_us=0.5)
    # fifo at 8 and 4 active cores x every contention level: one call.
    fifo = _sweep_rows(
        _cfg("fifo", **kw), {"seg_noncrit_us": nc_ax, "n_cores": [8, 4]},
        lambda c: f"bench5/mcs{c['n_cores']}/nc{c['seg_noncrit_us'][0]:g}")
    tas = _sweep_rows(
        _cfg("tas", w_big=8.0, **kw), {"seg_noncrit_us": nc_ax},
        lambda c: f"bench5/tas/nc{c['seg_noncrit_us'][0]:g}")
    asl = _sweep_rows(
        _cfg("libasl", default_window_us=1e5, **kw),
        {"seg_noncrit_us": nc_ax},
        lambda c: f"bench5/libasl/nc{c['seg_noncrit_us'][0]:g}")
    mcs8 = {r["name"].rsplit("nc", 1)[1]: r for r in fifo
            if "/mcs8/" in r["name"]}
    mcs4 = {r["name"].rsplit("nc", 1)[1]: r for r in fifo
            if "/mcs4/" in r["name"]}
    rows = []
    for nc, t, a in zip(ncs, tas, asl):
        key = f"{float(nc):g}"
        m8, m4 = mcs8[key], mcs4[key]
        rows.append(dict(name=f"bench5/nc{nc}", noncrit_us=nc,
                         tput_libasl=a["tput"], tput_mcs8=m8["tput"],
                         tput_mcs4=m4["tput"], tput_tas=t["tput"],
                         speedup_vs_mcs8=a["tput"] / m8["tput"],
                         speedup_vs_mcs4=a["tput"] / m4["tput"]))
    return rows


# ---------------------------------------------------------------------------
# Load-latency sweep (queue_flex-style): offered-load sweep -> throughput
# + P99 per policy on the stochastic workload model (repro.workloads):
# open-loop Poisson think times, lognormal services.  The load axis rides
# as the traced ``arrival_rate`` sweep dimension — one executable per
# policy for the whole curve.
# ---------------------------------------------------------------------------

# Step-utilization calibration for the merged load figures: events the
# simulator retires per 8 ms of sim, measured per (policy, load frac) on
# the M1 calibration (probe: run the figure grid at sim_time_us=8e3 and
# read st.events per lane).  Each cell's horizon is stretched by
# max(table)/table[cell], so every lane of the ONE merged executable
# retires ~the same event count — a vmapped while_loop steps ALL lanes
# until the last finishes, so equalizing per-lane event counts turns
# live-guard no-op steps into retired events (~3x device events/s; see
# docs/simulator.md §Fused step kernel & multi-policy executables).
# Low-load cells simply simulate longer (their tails get MORE samples);
# stale values only cost utilization, never correctness.
_LOADLAT_EV8MS = {
    ("fifo", 0.2): 606, ("fifo", 0.4): 1134, ("fifo", 0.6): 1612,
    ("fifo", 0.8): 1958, ("fifo", 0.9): 2094, ("fifo", 1.5): 2514,
    ("fifo", 3.0): 2427,
    ("tas", 0.2): 606, ("tas", 0.4): 1139, ("tas", 0.6): 1620,
    ("tas", 0.8): 1988, ("tas", 0.9): 2158, ("tas", 1.5): 2786,
    ("tas", 3.0): 3400,
    ("prop", 0.2): 606, ("prop", 0.4): 1150, ("prop", 0.6): 1620,
    ("prop", 0.8): 2013, ("prop", 0.9): 2200, ("prop", 1.5): 2938,
    ("prop", 3.0): 3822,
    ("libasl", 0.2): 615, ("libasl", 0.4): 1164, ("libasl", 0.6): 1677,
    ("libasl", 0.8): 2047, ("libasl", 0.9): 2254, ("libasl", 1.5): 2956,
    ("libasl", 3.0): 3257,
}
_OPENLOOP_EV8MS = {
    ("fifo", 0.2): 906, ("fifo", 0.4): 1734, ("fifo", 0.6): 2562,
    ("fifo", 0.8): 3300, ("fifo", 0.9): 3690, ("fifo", 1.1): 3934,
    ("shfl", 0.2): 906, ("shfl", 0.4): 1734, ("shfl", 0.6): 2562,
    ("shfl", 0.8): 3300, ("shfl", 0.9): 3691, ("shfl", 1.1): 4288,
    ("libasl", 0.2): 910, ("libasl", 0.4): 1761, ("libasl", 0.6): 2644,
    ("libasl", 0.8): 3479, ("libasl", 0.9): 3991, ("libasl", 1.1): 4443,
}

# Seed replicas per (policy, load) cell of the merged load figures: extra
# lanes in the same executable (near-free on the batched step), averaged
# back to one row per cell by _seed_mean.
LOADLAT_SEEDS = 6
OPENLOOP_SEEDS = 6


def _seed_mean(rows):
    """Collapse per-seed replica rows (rows sharing a name) to their mean.

    Numeric row keys average over finite replicas; string/dict keys keep
    the first replica's value.  The representative ``summary`` keeps the
    first replica's detail with ``events`` summed over ALL replicas, so
    the bench harness (benchmarks/simperf) counts every simulated event
    behind the row."""
    groups: dict = {}
    for r in rows:
        groups.setdefault(r["name"], []).append(r)
    out = []
    for grp in groups.values():
        r = dict(grp[0])
        for k, v in grp[0].items():
            if isinstance(v, bool) or not isinstance(
                    v, (int, float, np.integer, np.floating)):
                continue
            vals = np.asarray([g[k] for g in grp], float)
            fin = vals[np.isfinite(vals)]
            r[k] = float(fin.mean()) if fin.size else float("nan")
        r.pop("seed", None)
        r["n_seeds"] = len(grp)
        r["summary"] = dict(grp[0]["summary"], events=sum(
            g["summary"]["events"] for g in grp))
        out.append(r)
    return out


def _loadlat_rate(frac: float) -> float:
    """wl_rate that offers ``frac`` of lock capacity: bisect the
    utilization model U(r) = sum_c cs_c / (cs_c + think_c / r), with the
    per-core cs/think times derived from the same ``_cfg`` calibration
    the sweep runs (so a calibration change cannot desynchronize the
    load labels)."""
    cfg = _cfg("fifo", 8)
    cs = [sum(d * cfg.speed_cs[c] for d in cfg.seg_cs_us)
          for c in range(cfg.n_cores)]
    think = [(sum(cfg.seg_noncrit_us) + cfg.inter_epoch_us)
             * cfg.speed_nc[c] for c in range(cfg.n_cores)]

    def util(r):
        return sum(c / (c + th / r) for c, th in zip(cs, think))

    lo, hi = 1e-4, 1e4
    for _ in range(80):
        mid = (lo * hi) ** 0.5
        if util(mid) < frac:
            lo = mid
        else:
            hi = mid
    return float((lo * hi) ** 0.5)


def loadlat_sweep(slo=200.0):
    """Throughput + tail latency vs offered load, one curve per policy —
    the macro-benchmark shape of the paper's Table 1 databases.  The
    load grid is shared with the dispatch-fleet sweep
    (serving_bench.LOAD_FRACS).

    The whole policy x load grid is ONE merged multi-policy executable
    (cfg.policy_set): the policy rides traced in SimParams.pol_id, so
    the figure costs 1 compilation instead of one per policy.  Each cell
    runs LOADLAT_SEEDS replica lanes with horizon-equalized per-cell sim
    times (_LOADLAT_EV8MS) and _seed_mean folds them to one row."""
    from benchmarks.serving_bench import LOAD_FRACS
    # The shared grid plus two saturated points — the regime where the
    # policies separate (queue_flex's "excess tail latency" knee).
    fracs = tuple(LOAD_FRACS) + (1.5, 3.0)
    rates = [_loadlat_rate(f) for f in fracs]
    wl = dict(wl=True, wl_process="poisson", wl_service="lognormal",
              wl_cv=1.0, sim_time_us=80_000.0)
    specs = (("fifo", 1.0, 1e9), ("tas", 8.0, 1e9),
             ("prop", 1.0, 1e9), ("libasl", 1.0, slo))
    cfg = _cfg("fifo", 8, **wl, policy_set=tuple(p for p, _, _ in specs))
    emax = max(_LOADLAT_EV8MS.values())
    axes = {"policy": [], "arrival_rate": [], "w_big": [], "slo_us": [],
            "seed": [], "sim_time_us": []}
    for pol, w_big, slo_us in specs:
        for f, r in zip(fracs, rates):
            for seed in range(LOADLAT_SEEDS):
                axes["policy"].append(pol)
                axes["arrival_rate"].append(r)
                axes["w_big"].append(w_big)
                axes["slo_us"].append(slo_us)
                axes["seed"].append(seed)
                axes["sim_time_us"].append(
                    cfg.sim_time_us * emax / _LOADLAT_EV8MS[pol, f])
    rows = _sweep_rows(
        cfg, axes,
        lambda c: (f"loadlat/{c['policy']}/"
                   f"f{fracs[rates.index(c['arrival_rate'])]:.2f}"),
        product=False,
        extra=lambda c, s: dict(
            load_frac=fracs[rates.index(c["arrival_rate"])]))
    return _seed_mean(rows)


# ---------------------------------------------------------------------------
# Open-loop load-latency sweep: arrivals as events (cfg.wl_open), not
# think-scaling — each core runs an open queue, so epoch latency is the
# full sojourn from arrival and the curves show the classic open-loop
# knee (latency diverges at the saturation point instead of the
# closed-loop's self-throttled plateau).  The load axis is the traced
# ``arrival_rate`` — one executable per policy for the whole curve.
# ---------------------------------------------------------------------------

def _openloop_rate(frac: float) -> float:
    """wl_rate that offers ``frac`` of lock capacity in open-loop mode:
    core ``c`` contributes ``rate / base_c`` arrivals per us (base = its
    closed-loop think budget ``(noncrit0 + inter) * speed_nc``), each
    holding the lock for its CS time."""
    cfg = _cfg("fifo", 8)
    cs = [sum(d * cfg.speed_cs[c] for d in cfg.seg_cs_us)
          for c in range(cfg.n_cores)]
    base = [(cfg.seg_noncrit_us[0] + cfg.inter_epoch_us) * cfg.speed_nc[c]
            for c in range(cfg.n_cores)]
    return frac / sum(c / b for c, b in zip(cs, base))


def openloop_loadlat(slo=300.0):
    """Open-loop offered load -> throughput + sojourn P99 per policy
    (fifo baseline, the paper's libasl, and the shfl plugin — the two
    throughput-first points bracket the AIMD policy).

    Like loadlat_sweep, the whole grid is ONE merged multi-policy
    executable with horizon-equalized seed-replica lanes (the open-loop
    figures are the bench harness's device events/s acceptance floor)."""
    from benchmarks.serving_bench import LOAD_FRACS
    fracs = tuple(LOAD_FRACS) + (1.1,)     # one past-saturation point
    rates = [_openloop_rate(f) for f in fracs]
    wl = dict(wl=True, wl_open=True, wl_process="poisson",
              wl_service="lognormal", wl_cv=1.0, sim_time_us=60_000.0)
    specs = (("fifo", 1e9), ("shfl", 1e9), ("libasl", slo))
    cfg = _cfg("fifo", 8, **wl, policy_set=tuple(p for p, _ in specs))
    emax = max(_OPENLOOP_EV8MS.values())
    axes = {"policy": [], "arrival_rate": [], "slo_us": [],
            "seed": [], "sim_time_us": []}
    for pol, slo_us in specs:
        for f, r in zip(fracs, rates):
            for seed in range(OPENLOOP_SEEDS):
                axes["policy"].append(pol)
                axes["arrival_rate"].append(r)
                axes["slo_us"].append(slo_us)
                axes["seed"].append(seed)
                axes["sim_time_us"].append(
                    cfg.sim_time_us * emax / _OPENLOOP_EV8MS[pol, f])
    rows = _sweep_rows(
        cfg, axes,
        lambda c: (f"openloop/{c['policy']}/"
                   f"f{fracs[rates.index(c['arrival_rate'])]:.2f}"),
        product=False,
        extra=lambda c, s: dict(
            load_frac=fracs[rates.index(c["arrival_rate"])]))
    return _seed_mean(rows)


# ---------------------------------------------------------------------------
# Chaos collapse: throughput / P99 / goodput vs lock-holder preemption
# rate, one curve per registered policy (docs/faults.md).  Preemption is
# asymmetric — ``fault_mask`` makes only the little cores preemptible
# (scheduler pressure lands on the efficiency cores) — so FIFO craters
# (its round-robin handoff parks the lock on a preemptible core 1/2 the
# time and the whole convoy eats each stall) while policies that keep
# the lock on big cores inside their SLO slack (LibASL, TAS-big) dodge
# the stalls and degrade gracefully.  The preemption axis rides traced
# (sweep() flips the static gate): the whole grid is one executable per
# policy.
# ---------------------------------------------------------------------------

CHAOS_RATES = (0.0, 0.02, 0.05, 0.1, 0.2)


def chaos_collapse(slo=300.0):
    from repro.core.policies import REGISTRY
    rows = []
    for pol in REGISTRY:
        base = _cfg(pol, 8)
        cfg = _cfg(pol, 8, sim_time_us=60_000.0,
                   preempt_scale_us=50.0,
                   fault_mask=tuple(0.0 if b else 1.0 for b in base.big),
                   **FIG1_KW.get(pol, {}))
        rows += _sweep_rows(
            cfg, {"preempt_rate": list(CHAOS_RATES)},
            lambda c, p=pol: f"chaos/{p}/pr{c['preempt_rate']:g}",
            slo_us=slo,
            extra=lambda c, s: dict(
                slo_us=slo, goodput_eps=s["goodput_eps"],
                slo_good_frac=s["slo_good_frac"]))
    return rows


# ---------------------------------------------------------------------------
# Energy efficiency: throughput-per-watt + EDP vs big:little mix, one
# curve per registered policy (docs/energy.md).  Little cores draw a
# fraction of a big core's watts (energy.amp_power, Cortex-A15/A7
# class) but also retire CS work 3.75x slower — whether racing the lock
# onto big cores wins on J/op is the question this figure answers per
# policy.  Every per-core table of a mix — the big bit, both speed
# tables and the four phase-power tables — rides as one zipped traced
# table axis, so the whole mix column is ONE executable per policy.
# ---------------------------------------------------------------------------

ENERGY_MIXES = (8, 6, 4, 2, 0)       # n_big of 8 cores


def energy_efficiency(sim_time_us=60_000.0):
    from repro.core.policies import REGISTRY
    mixes = []
    for n_big in ENERGY_MIXES:
        big = (1,) * n_big + (0,) * (8 - n_big)
        mixes.append(dict(
            big=big,
            speed_cs=tuple(1.0 if b else CS_RATIO for b in big),
            speed_nc=tuple(1.0 if b else NC_RATIO for b in big),
            **energy.amp_power(big)))
    axes = {k: [m[k] for m in mixes] for k in mixes[0]}
    rows = []
    for pol in REGISTRY:
        cfg = _cfg(pol, 8, sim_time_us=sim_time_us,
                   **FIG1_KW.get(pol, {}))
        rows += _sweep_rows(
            cfg, axes,
            lambda c, p=pol: f"energy/{p}/big{sum(c['big'])}",
            slo_us=FIG1_SLO.get(pol, 1e9), product=False,
            extra=lambda c, s: dict(
                n_big=int(sum(c["big"])),
                energy_j=s["energy_j"], power_w=s.get("power_w"),
                tput_per_watt=s.get("tput_per_watt"),
                edp=s.get("edp")))
    return rows


# ---------------------------------------------------------------------------
# Bench-6: blocking locks / oversubscription — wakeup latency on the
# FIFO handoff path; LibASL standbys dodge it (wakeup is a traced axis)
# ---------------------------------------------------------------------------

def bench6_blocking():
    """Blocking locks: FIFO handoff pays the parked-waiter wakeup latency on
    *every* transfer; LibASL standby grabs (busy-poll during the window)
    dodge it.  The simulator models the wakeup cost, not the full OS
    scheduler, so this shows the degradation *trend* rather than the
    paper's 96% pthread-vs-MCS gap (limitation noted in EXPERIMENTS.md)."""
    wk = [0.0, 8.0, 20.0]
    rows = _sweep_rows(
        _bench1_cfg("fifo", wakeup_us=20.0), {"wakeup_us": wk},
        lambda c: f"bench6/mcs-park/w{c['wakeup_us']:.0f}")
    rows += _sweep_rows(
        _bench1_cfg("libasl", wakeup_us=20.0), {"wakeup_us": wk},
        lambda c: f"bench6/libasl-block/w{c['wakeup_us']:.0f}",
        slo_us=1e5)
    return rows


# ---------------------------------------------------------------------------
# Key-sharded datastore: hot-key contention collapse per dispatch policy
# + throughput vs Zipf exponent (docs/workloads.md §Key-sharded traffic).
# One ZIPPED sweep per policy — the theta column (5 exponents at 16
# locks) and the lock-count column (1..8 locks at YCSB theta 0.99) ride
# in the same batched call, so the whole figure is ONE executable per
# policy.  Plain fifo under the keyed config IS the CRCW baseline (any
# core may access any bucket, strict arrival order) — labeled ``crcw``.
# ---------------------------------------------------------------------------

KEYSHARD_THETAS = (0.0, 0.5, 0.9, 0.99, 1.2)
KEYSHARD_LOCKS = (1, 2, 4, 8)
KEYSHARD_POLICIES = (("fifo", "crcw"), ("ks_erew", "erew"),
                     ("ks_crew", "crew"), ("ks_jbsq", "jbsq"))


def keyshard(n_keys=4096, n_locks=16):
    axes = {
        "zipf_theta": list(KEYSHARD_THETAS) + [0.99] * len(KEYSHARD_LOCKS),
        "n_locks": [n_locks] * len(KEYSHARD_THETAS) + list(KEYSHARD_LOCKS),
    }
    rows = []
    for pol, label in KEYSHARD_POLICIES:
        cfg = _cfg(pol, 8, n_locks=n_locks, n_keys=n_keys)
        rows += _sweep_rows(
            cfg, axes,
            lambda c, p=label: (f"keyshard/{p}/th{c['zipf_theta']:g}"
                                f"_l{int(c['n_locks'])}"),
            product=False,
            extra=lambda c, s, p=label: dict(
                label=p, zipf_theta=float(c["zipf_theta"]),
                n_locks=int(c["n_locks"]), n_keys=n_keys))
    return rows


# ---------------------------------------------------------------------------
# Excess tail beyond the SLO vs offered load — the streaming-histogram
# figure (docs/simulator.md §Streaming metrics).  P99/P999 come from the
# constant-memory on-device histograms (cfg.hist), so the tail covers the
# FULL run history even where the per-core sample rings wrapped; each row
# records how far the percentile overshoots the SLO
# (``excess_p99 = max(0, P99/SLO - 1)``).  The whole policy x load grid
# is ONE merged multi-policy executable (cfg.policy_set), matching the
# loadlat figures' protocol.
# ---------------------------------------------------------------------------

EXCESS_TAIL_SLO = 200.0


def excess_tail(slo=EXCESS_TAIL_SLO):
    from benchmarks.serving_bench import LOAD_FRACS
    fracs = tuple(LOAD_FRACS) + (1.5,)     # one saturated point: the knee
    rates = [_loadlat_rate(f) for f in fracs]
    specs = (("fifo", 1.0, 1e9), ("tas", 8.0, 1e9), ("libasl", 1.0, slo))
    cfg = _cfg("fifo", 8, sim_time_us=40_000.0, wl=True,
               wl_process="poisson", wl_service="lognormal", wl_cv=1.0,
               hist=True, policy_set=tuple(p for p, _, _ in specs))
    axes = {"policy": [], "arrival_rate": [], "w_big": [], "slo_us": []}
    for pol, w_big, slo_us in specs:
        for r in rates:
            axes["policy"].append(pol)
            axes["arrival_rate"].append(r)
            axes["w_big"].append(w_big)
            axes["slo_us"].append(slo_us)

    def _extra(c, s):
        p99, p999 = s["ep_p99_hist_all_us"], s["ep_p999_hist_all_us"]
        return dict(
            load_frac=fracs[rates.index(c["arrival_rate"])],
            slo_us=slo,
            ep_p99_hist_us=p99, ep_p999_hist_us=p999,
            excess_p99=max(0.0, p99 / slo - 1.0),
            excess_p999=max(0.0, p999 / slo - 1.0),
            hist_rel_err_bound=s["hist_rel_err_bound"],
            tail_truncated=bool(s.get("tail_truncated", False)))

    return _sweep_rows(
        cfg, axes,
        lambda c: (f"excess/{c['policy']}/"
                   f"f{fracs[rates.index(c['arrival_rate'])]:.2f}"),
        product=False, extra=_extra)


ALL = {
    "fig1_collapse": fig1_collapse,
    "fig4_big_affinity": fig4_big_affinity,
    "fig5_proportional": fig5_proportional,
    "bench1_contended": bench1_contended,
    "bench1_slo_sweep": bench1_slo_sweep,
    "bench2_variable": bench2_variable,
    "bench3_mixed": bench3_mixed,
    "bench4_scalability": bench4_scalability,
    "bench5_contention": bench5_contention,
    "bench6_blocking": bench6_blocking,
    "loadlat_sweep": loadlat_sweep,
    "openloop_loadlat": openloop_loadlat,
    "chaos_collapse": chaos_collapse,
    "energy_efficiency": energy_efficiency,
    "keyshard": keyshard,
    "excess_tail": excess_tail,
}
