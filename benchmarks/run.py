"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall
microseconds per produced row; derived = the figure's headline metric) and
writes full JSON to artifacts/bench/results.json.

Sections:
  sim            — CI smoke gate: fig1's batched-vs-seed acceptance bench
                   (speedup floor, <= 1 executable per registered policy),
                   a policy-matrix probe (every registered lock policy
                   runs one tiny cell), energy-layer probes (zero-power
                   purity, energy == integral-of-power conservation, the
                   energy_efficiency figure's one-executable-per-policy
                   discipline), a keyshard probe (EREW beats the CRCW
                   baseline under hot-key Zipf traffic, executable
                   ceiling kept), a merged-executable probe (a
                   fig1-shaped policy x n_cores grid compiles <= 2
                   executables via cfg.policy_set), an open-loop
                   events/s floor on the recorded BENCH_simlock.json
                   + a sharded-vs-unsharded sweep parity
                   probe; nonzero exit on failure.
                   Opt-in (not part of the default all-sections run): it
                   virtualizes 8 host devices and pins XLA threading,
                   which would skew the other sections' baselines
  paper figures  — discrete-event AMP simulator (benchmarks/paper_figs.py)
  serving/fleet  — engine + dispatch + straggler sims (serving_bench.py);
                   also a CI gate: ASL must hold its TTFT P99 within
                   1.5x its SLO and FIFO must not beat ASL on token
                   throughput — nonzero exit on a break
  kernels        — per-kernel interpret-mode check vs jnp reference
  roofline       — reads artifacts/roofline/*.json (produced by
                   ``python -m benchmarks.roofline``; compile-heavy)
  chaos          — CI gate for the fault-injection layer
                   (docs/faults.md): every registered policy stays live
                   under combined faults, zero-rate injection is
                   bit-identical to fault-free, and LibASL's goodput
                   under maximum preemption stays >= FIFO's.  Opt-in
                   (re-runs the chaos_collapse figure)

The smoke gates are ``--section sim --quick``,
``--section serving --quick`` and ``--section chaos --quick``.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks._jax_cache import enable_persistent_cache

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"


def _emit(name, us_per_call, derived):
    print(f"{name},{us_per_call:.3f},{derived}")


def _run_section(section: str, fns: dict, results: dict):
    for name, fn in fns.items():
        t0 = time.time()
        rows = fn()
        dt_us = (time.time() - t0) * 1e6
        results[f"{section}/{name}"] = rows
        derived = _headline(name, rows)
        _emit(f"{section}/{name}", dt_us / max(len(rows), 1), derived)


def _headline(name, rows) -> str:
    try:
        if name.startswith("fig1"):
            f4 = next(r for r in rows if r["policy"] == "fifo"
                      and r["n_threads"] == 4)
            f8 = next(r for r in rows if r["policy"] == "fifo"
                      and r["n_threads"] == 8)
            t8 = next(r for r in rows if r["policy"] == "tas"
                      and r["n_threads"] == 8)
            return (f"mcs_drop={1 - f8['tput'] / f4['tput']:.0%};"
                    f"tas_p99_vs_mcs={t8['p99_all'] / f8['p99_all']:.1f}x")
        if name.startswith("fig4"):
            f8 = next(r for r in rows if r["policy"] == "fifo"
                      and r["n_threads"] == 8)
            t8 = next(r for r in rows if r["policy"] == "tas"
                      and r["n_threads"] == 8)
            return (f"tas_tput_vs_mcs={t8['tput'] / f8['tput']:.2f}x;"
                    f"tas_p99_vs_mcs="
                    f"{t8['ep_p99_little'] / f8['ep_p99_little']:.1f}x")
        if name.startswith("fig5"):
            return ";".join(f"p{r['proportion']}:{r['tput']:.0f}/"
                            f"{r['ep_p99_little']:.0f}us" for r in rows)
        if name == "bench1_contended":
            mcs = next(r for r in rows if r["name"].endswith("mcs"))
            mx = next(r for r in rows if r["name"].endswith("MAX"))
            return f"libaslMAX_vs_mcs={mx['tput'] / mcs['tput']:.2f}x"
        if name == "bench1_slo_sweep":
            track = [abs(r["ep_p99_little"] - r["slo_us"]) / r["slo_us"]
                     for r in rows if 40 <= r["slo_us"] <= 300]
            return f"slo_tracking_err_med={np.median(track):.1%}"
        if name == "bench2_variable":
            ach = max(r["violation_excess"] for r in rows if r["achievable"])
            fell_back = rows[-1]["mean_window_us"] < 5.0
            return (f"achievable_excess={ach:.1%};"
                    f"impossible_phase_fell_back_to_fifo={fell_back}")
        if name == "bench3_mixed":
            return ";".join(f"{r['short_pct']}%:{r['tput_vs_mcs']:.2f}x"
                            for r in rows)
        if name == "bench4_scalability":
            mx = next(r for r in rows if "MAX" in r["name"]
                      and r["n_threads"] == 8)
            f4 = next(r for r in rows if r["policy"] == "fifo"
                      and r["n_threads"] == 4)
            return f"libaslMAX8_vs_mcs4={mx['tput'] / f4['tput']:.2f}x"
        if name == "bench5_contention":
            lo = rows[-1]
            hi = rows[0]
            return (f"low_contention_vs_mcs4={lo['speedup_vs_mcs4']:.2f}x;"
                    f"high_vs_mcs8={hi['speedup_vs_mcs8']:.2f}x")
        if name == "bench6_blocking":
            by = {(r["name"].split("/")[1], r["wakeup_us"]): r
                  for r in rows}
            mcs_deg = by[("mcs-park", 0.0)]["tput"] / \
                by[("mcs-park", 20.0)]["tput"]
            asl_deg = by[("libasl-block", 0.0)]["tput"] / \
                by[("libasl-block", 20.0)]["tput"]
            rel = by[("libasl-block", 20.0)]["tput"] / \
                by[("mcs-park", 20.0)]["tput"]
            return (f"wakeup20us:mcs_degrades={mcs_deg:.2f}x,"
                    f"libasl_degrades={asl_deg:.2f}x,"
                    f"libasl_vs_mcs={rel:.2f}x")
        if name == "db_serving":
            by = {r["name"].split("/")[-1]: r for r in rows}
            return (f"asl_ttft_p99={by['asl']['ttft_p99'] * 1e3:.0f}ms(viol"
                    f"={by['asl']['slo_violation_rate']:.0%});"
                    f"fifo_itl_p99={by['fifo']['itl_p99'] * 1e3:.0f}ms;"
                    f"asl_itl_p99={by['asl']['itl_p99'] * 1e3:.0f}ms")
        if name == "dispatch_fleet":
            fr = sorted({r["load_frac"] for r in rows})
            g = {r["name"].split("/")[1]: r for r in rows
                 if r["load_frac"] == fr[0]}
            h = {r["name"].split("/")[1]: r for r in rows
                 if r["load_frac"] == fr[-1]}
            return (f"low:asl_p99={g['asl']['p99'] * 1e3:.0f}ms_vs_fair="
                    f"{g['fair']['p99'] * 1e3:.0f}ms;"
                    f"high:asl_rps={h['asl']['throughput_rps']:.0f}_vs_"
                    f"fastonly={h['fast-only']['throughput_rps']:.0f}")
        if name == "db_multiclass":
            asl = next(r for r in rows if r["name"].endswith("asl"))
            return (f"asl:lc_p99={asl['latency-critical/ttft_p99']:.2f}s,"
                    f"be_p99={asl['best-effort/ttft_p99']:.2f}s")
        if name == "loadlat_sweep":
            hi = max(r["load_frac"] for r in rows)
            h = {r["policy"]: r for r in rows if r["load_frac"] == hi}
            return (f"load{hi:.0%}:libasl_tput_vs_mcs="
                    f"{h['libasl']['tput'] / h['fifo']['tput']:.2f}x;"
                    f"libasl_p99={h['libasl']['ep_p99_little']:.0f}us"
                    f"_vs_mcs={h['fifo']['ep_p99_little']:.0f}us")
        if name == "openloop_loadlat":
            hi = max(r["load_frac"] for r in rows)
            lo = min(r["load_frac"] for r in rows)
            g = {r["policy"]: r for r in rows if r["load_frac"] == lo}
            h = {r["policy"]: r for r in rows if r["load_frac"] == hi}
            knee = h["fifo"]["ep_p99_all"] / max(g["fifo"]["ep_p99_all"],
                                                 1e-9)
            return (f"openloop_knee_fifo={knee:.0f}x_p99;"
                    f"sat:shfl_tput_vs_fifo="
                    f"{h['shfl']['tput'] / h['fifo']['tput']:.2f}x;"
                    f"libasl_little_p99={h['libasl']['ep_p99_little']:.0f}us")
        if name == "chaos_collapse":
            mx = max(r["preempt_rate"] for r in rows)
            h = {r["policy"]: r for r in rows if r["preempt_rate"] == mx}
            z = {r["policy"]: r for r in rows if r["preempt_rate"] == 0.0}
            return (f"pr{mx:g}:fifo_drop="
                    f"{1 - h['fifo']['tput'] / z['fifo']['tput']:.0%};"
                    f"libasl_goodput_vs_fifo="
                    f"{h['libasl']['goodput_eps'] / h['fifo']['goodput_eps']:.2f}x")
        if name == "energy_efficiency":
            full = {r["policy"]: r for r in rows if r["n_big"] == 8}
            lit = {r["policy"]: r for r in rows if r["n_big"] == 0}
            best = max(rows, key=lambda r: r["tput_per_watt"])
            return (f"little_power_vs_big="
                    f"{lit['fifo']['power_w'] / full['fifo']['power_w']:.2f}x;"
                    f"little_tput_vs_big="
                    f"{lit['fifo']['tput'] / full['fifo']['tput']:.2f}x;"
                    f"best_tputW={best['name']}"
                    f"@{best['tput_per_watt']:.0f}")
        if name == "keyshard":
            hot = {r["label"]: r for r in rows
                   if r["n_locks"] == 1 and r["zipf_theta"] == 0.99}
            th = {r["zipf_theta"]: r for r in rows
                  if r["label"] == "crcw" and r["n_locks"] == 16}
            return (f"hot1lock:erew_vs_crcw="
                    f"{hot['erew']['tput'] / hot['crcw']['tput']:.2f}x,"
                    f"jbsq_vs_crcw="
                    f"{hot['jbsq']['tput'] / hot['crcw']['tput']:.2f}x;"
                    f"crcw_th1.2_vs_uniform="
                    f"{th[1.2]['tput'] / th[0.0]['tput']:.2f}x")
        if name == "excess_tail":
            hi = max(r["load_frac"] for r in rows)
            h = {r["policy"]: r for r in rows if r["load_frac"] == hi}
            return (f"sat:fifo_excess_p999={h['fifo']['excess_p999']:.1f}x"
                    f"_vs_libasl={h['libasl']['excess_p999']:.1f}x;"
                    f"bound={h['fifo']['hist_rel_err_bound']:.1%}")
        if name == "straggler_training":
            by = {r["name"].split("/")[-1]: r for r in rows}
            return (f"asl_vs_sync={by['asl-staleness']['steps_per_s'] / by['sync']['steps_per_s']:.2f}x;"
                    f"p99_staleness={by['asl-staleness']['p99_staleness']:.0f}")
    except Exception as e:  # headline must never kill the run
        return f"(headline error: {e})"
    return ""


def _kernel_bench(results):
    """Interpret-mode kernel check + timing vs jnp reference."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, h, kh, s, dh = 1, 4, 2, 512, 64
    q = jax.random.normal(ks[0], (b, h, s, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, kh, s, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, kh, s, dh), jnp.float32)
    t0 = time.time()
    out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    jax.block_until_ready(out)
    dt = (time.time() - t0) * 1e6
    err = float(jnp.max(jnp.abs(
        out - ref.flash_attention_ref(q, k, v, causal=True))))
    results["kernels/flash_attention"] = {"err": err, "us": dt}
    _emit("kernels/flash_attention_interp", dt, f"max_err={err:.1e}")


def _policy_matrix_probe(results) -> bool:
    """Every registered lock policy runs one tiny sweep cell — a cheap
    canary that a policy (or the registry wiring) broke, and that the
    one-executable-per-policy discipline holds: the probe may compile at
    most one new batched executable per registered policy."""
    import numpy as np

    from repro.core import simlock as sl
    from repro.core.policies import REGISTRY

    n0 = sl.n_batch_executables()
    probe, ok = {}, True
    for name in REGISTRY:
        try:
            cfg = sl.SimConfig(policy=name, sim_time_us=1_000.0)
            st, _ = sl.sweep(cfg, {"seed": [0, 1]}, slo_us=60.0)
            events = int(np.sum(np.asarray(st.events)))
            alive = events > 0
            probe[name] = {"events": events, "ok": bool(alive)}
            ok = ok and alive
        except Exception as e:                      # noqa: BLE001
            probe[name] = {"error": repr(e), "ok": False}
            ok = False
    new_execs = sl.n_batch_executables() - n0
    if new_execs > len(REGISTRY):
        ok = False
    results["sim/policy_matrix"] = {
        "policies": sorted(REGISTRY), "probe": probe,
        "new_executables": new_execs, "registry_size": len(REGISTRY),
        "pass": bool(ok)}
    bad = [n for n, p in probe.items() if not p["ok"]]
    _emit("sim/policy_matrix", 0.0,
          f"policies={len(REGISTRY)};execs={new_execs}"
          f"(<= {len(REGISTRY)});"
          + (f"broken={','.join(bad)};" if bad else "")
          + ("PASS" if ok else "FAIL"))
    return ok


def _energy_probe(results) -> bool:
    """CI probes for the energy/DVFS layer (docs/energy.md):

    1. purity — for every registered policy, a zero-power default-DVFS
       run is bit-identical to a gate-off run on every SimState leaf
       (the layer off is provably a no-op);
    2. conservation — uniform 1 W in every phase integrates to
       n_cores x sim-seconds (energy == integral of power dt, the
       telescoping event-step sum);
    3. batching + asymmetry — the energy_efficiency figure compiles at
       most one executable per registered policy, and the all-little
       mix draws less power AND less throughput than the all-big mix.
    """
    import jax
    import numpy as np

    from benchmarks import paper_figs
    from repro.core import simlock as sl
    from repro.core.policies import REGISTRY

    horizon = 4_000.0
    pure_ok = True
    for name in sorted(REGISTRY):
        base = sl.SimConfig(policy=name, sim_time_us=horizon)
        zero = sl.with_columns(base, dvfs=(1.0,) * 8,
                               p_cs=(0.0,) * 8, p_spin=(0.0,) * 8,
                               p_park=(0.0,) * 8, p_idle=(0.0,) * 8)
        a, b = sl.run(base, 60.0), sl.run(zero, 60.0)
        pure_ok = pure_ok and all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    _emit("sim/energy_purity", 0.0,
          f"policies={len(REGISTRY)};zero_power_bit_identical={pure_ok};"
          + ("PASS" if pure_ok else "FAIL"))

    cfg = sl.with_columns(
        sl.SimConfig(policy="fifo", sim_time_us=horizon),
        p_cs=(1.0,) * 8, p_spin=(1.0,) * 8, p_park=(1.0,) * 8,
        p_idle=(1.0,) * 8)
    s = sl.summarize(cfg, jax.tree.map(np.asarray, sl.run(cfg, 1e9)))
    want = cfg.n_cores * cfg.sim_time_us * 1e-6
    cons_ok = abs(s["energy_j"] - want) <= 0.02 * want
    _emit("sim/energy_conservation", 0.0,
          f"energy_j={s['energy_j']:.4f}_vs_integral={want:.4f};"
          + ("PASS" if cons_ok else "FAIL"))

    n0 = sl.n_batch_executables()
    rows = paper_figs.energy_efficiency()
    execs = sl.n_batch_executables() - n0
    results["sim/energy_efficiency"] = rows
    batch_ok = execs <= len(REGISTRY)
    lit = {r["policy"]: r for r in rows if r["n_big"] == 0}
    full = {r["policy"]: r for r in rows if r["n_big"] == 8}
    amp_ok = all(lit[p]["power_w"] < full[p]["power_w"]
                 and lit[p]["tput"] < full[p]["tput"] for p in lit)
    _emit("sim/energy_efficiency", 0.0,
          f"execs={execs}(<= {len(REGISTRY)});"
          f"littles_less_power_and_tput={amp_ok};"
          + ("PASS" if batch_ok and amp_ok else "FAIL"))

    ok = bool(pure_ok and cons_ok and batch_ok and amp_ok)
    results["sim/energy_gate"] = {
        "zero_power_bit_identical": bool(pure_ok),
        "conservation_energy_j": float(s["energy_j"]),
        "conservation_want_j": float(want),
        "figure_executables": int(execs),
        "registry_size": len(REGISTRY),
        "littles_less_power_and_tput": bool(amp_ok),
        "pass": ok}
    return ok


def _keyshard_probe(results) -> bool:
    """CI probe for the key-sharded datastore axis (docs/workloads.md
    §Key-sharded traffic): under hot-key traffic (Zipf theta 1.2 over 4
    bucket locks) the EREW owner-affinity policy must out-throughput the
    CRCW baseline (plain fifo under the keyed config) — big cores retire
    critical sections 3.75x faster, so pinning hot buckets to big-core
    owners wins robustly (the comparison is bit-deterministic at a fixed
    seed).  The probe may compile at most one new batched executable per
    probed policy (the keyshard figure's own discipline)."""
    from repro.core import simlock as sl

    kw = dict(sim_time_us=4_000.0, n_locks=4, n_keys=1024,
              zipf_theta=1.2)
    n0 = sl.n_batch_executables()
    tput = {}
    for name in ("fifo", "ks_erew"):
        cfg = sl.SimConfig(policy=name, **kw)
        st, grid = sl.sweep(cfg, {"seed": [3]}, slo_us=60.0)
        s = sl.sweep_summaries(cfg, st, grid)[0]
        tput[name] = float(s["throughput_epochs_per_s"])
    execs = sl.n_batch_executables() - n0
    order_ok = tput["ks_erew"] > tput["fifo"]
    exec_ok = execs <= 2
    ok = bool(order_ok and exec_ok)
    results["sim/keyshard"] = {
        "tput_eps": tput, "new_executables": int(execs),
        "hot_key_order_ok": bool(order_ok), "pass": ok}
    _emit("sim/keyshard", 0.0,
          f"hotkey:erew={tput['ks_erew']:.0f}_vs_crcw={tput['fifo']:.0f};"
          f"execs={execs}(<=2);" + ("PASS" if ok else "FAIL"))
    return ok


def _hist_tail_probe(results) -> bool:
    """CI probe for the constant-memory streaming-histogram tail
    metrics (docs/simulator.md §Streaming metrics).  On a tiny
    un-wrapped grid:

    * the histogram P99 must land within the documented one-bucket
      relative-error bound of the exact ring-buffer percentile;
    * the hist-on sweep may compile at most ONE new executable;
    * gate-off purity — every state leaf the two runs share must be
      bitwise identical (the static gate adds the histogram leaves, it
      never perturbs the event trajectory)."""
    import dataclasses

    import numpy as np

    from repro.core import simlock as sl

    cfg_off = sl.SimConfig(policy="libasl", sim_time_us=3_000.0)
    cfg_on = dataclasses.replace(cfg_off, hist=True)
    st_off, _ = sl.sweep(cfg_off, {"seed": [3]}, slo_us=60.0)
    n0 = sl.n_batch_executables()
    st_on, grid = sl.sweep(cfg_on, {"seed": [3]}, slo_us=60.0)
    execs = sl.n_batch_executables() - n0

    import jax

    def _eq(a, b):
        xs, ys = jax.tree.leaves(a), jax.tree.leaves(b)
        return len(xs) == len(ys) and all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(xs, ys))

    pure = all(
        _eq(getattr(st_on, f), getattr(st_off, f))
        for f in st_on._fields if f not in ("ep_hist", "cs_hist"))
    s = sl.sweep_summaries(cfg_on, st_on, grid, slo_us=60.0)[0]
    exact = s["ep_p99_all_us"]          # un-wrapped: the ring is exact
    est = s["ep_p99_hist_all_us"]
    bound = s["hist_rel_err_bound"]
    err = abs(est - exact) / max(exact, 1e-9)
    wrapped = bool(s.get("tail_truncated", False))
    ok = bool(err <= bound and pure and execs <= 1 and not wrapped)
    results["sim/hist_tail"] = {
        "p99_exact_us": exact, "p99_hist_us": est,
        "rel_err": err, "bound": bound, "gate_off_pure": bool(pure),
        "new_executables": int(execs), "wrapped": wrapped, "pass": ok}
    _emit("sim/hist_tail", 0.0,
          f"p99:hist={est:.1f}us_vs_exact={exact:.1f}us"
          f"(err={err:.2%}<={bound:.2%});pure={pure};"
          f"execs={execs}(<=1);" + ("PASS" if ok else "FAIL"))
    return ok


# Device events/s floors for the two open-loop figures: >= ~5x the
# pre-merge BENCH_simlock.json entries (openloop_loadlat 17609 ev/s,
# loadlat_sweep 19057 ev/s — the per-policy executables before the
# fused multi-policy sweep).  The gate reads the checked-in protocol
# file, so the speedup cannot regress silently between recordings.
OPENLOOP_EVS_FLOOR = 88_000
LOADLAT_EVS_FLOOR = 95_000


def _merged_exec_probe(results) -> bool:
    """The merged multi-policy executable discipline: a fig1-shaped grid
    (every registered policy x n_cores 1..8) swept with the full
    registry as ``policy_set`` must compile at most 2 executables —
    down from the per-policy path's <= n_policies — and every cell must
    retire events (a policy whose handlers are not switch-merge-safe
    would go silent or corrupt its neighbours)."""
    import numpy as np

    from repro.core import simlock as sl
    from repro.core.policies import REGISTRY

    names = tuple(REGISTRY)
    cfg = sl.SimConfig(policy=names[0], policy_set=names,
                       sim_time_us=1_500.0)
    axes = {"policy": [], "n_cores": []}
    for name in names:
        for n in range(1, 9):
            axes["policy"].append(name)
            axes["n_cores"].append(n)
    n0 = sl.n_batch_executables()
    t0 = time.time()
    st, _ = sl.sweep(cfg, axes, slo_us=60.0, product=False)
    ev = np.asarray(st.events)
    wall = time.time() - t0
    execs = sl.n_batch_executables() - n0
    alive = bool((ev > 0).all())
    ok = bool(execs <= 2 and alive)
    results["sim/merged_executable"] = {
        "cells": int(ev.size), "policies": len(names),
        "new_executables": int(execs), "all_cells_alive": alive,
        "wall_s": round(wall, 2), "pass": ok}
    _emit("sim/merged_executable", wall * 1e6 / ev.size,
          f"cells={ev.size};policies={len(names)};execs={execs}(<=2);"
          f"all_alive={alive};" + ("PASS" if ok else "FAIL"))
    return ok


def _openloop_floor_gate(results) -> bool:
    """The recorded open-loop device throughput cannot silently regress:
    BENCH_simlock.json (the checked-in simperf protocol) must show the
    merged open-loop figures at/above the floors derived from the
    pre-merge before/after, with fewer compilations than policies."""
    bench = ART.parents[1] / "BENCH_simlock.json"
    if not bench.exists():
        results["sim/openloop_floor"] = {"pass": False,
                                         "error": "no BENCH_simlock.json"}
        _emit("sim/openloop_floor", 0.0, "no BENCH_simlock.json;FAIL")
        return False
    figs = json.loads(bench.read_text()).get("figures", {})
    checks = {}
    ok = True
    for name, floor, n_pol in (("openloop_loadlat", OPENLOOP_EVS_FLOOR, 3),
                               ("loadlat_sweep", LOADLAT_EVS_FLOOR, 4)):
        d = figs.get(name, {})
        evs = d.get("events_per_s") or 0
        merged = d.get("compilations", n_pol) < n_pol
        checks[name] = {"events_per_s": evs, "floor": floor,
                        "compilations": d.get("compilations"),
                        "policies": n_pol, "merged": merged}
        ok = ok and evs >= floor and merged
    results["sim/openloop_floor"] = {"checks": checks, "pass": bool(ok)}
    _emit("sim/openloop_floor", 0.0,
          ";".join(f"{n}={c['events_per_s']}ev/s(>={c['floor']}),"
                   f"compiles={c['compilations']}(<{c['policies']})"
                   for n, c in checks.items())
          + (";PASS" if ok else ";FAIL"))
    return bool(ok)


def _sim_section(results, quick: bool) -> bool:
    """CI smoke gate for the simulator engine.  Runs the fig1 batched-vs-
    seed acceptance bench (the BENCH_simlock.json protocol, abridged) and
    a sharded-vs-unsharded parity probe; returns False on a gate break."""
    import jax
    import numpy as np

    from benchmarks import simperf
    from repro.core import simlock as sl

    rec = simperf.bench_fig1_batched_vs_seed(quick)
    results["sim/fig1_sweep"] = rec
    # --quick horizons are compile-dominated, so the wall ratio reads low
    # on a cold compile cache; the full >= 3 acceptance number is owned by
    # the cache-cold simperf protocol (BENCH_simlock.json).  The smoke
    # floor still catches a de-batched engine (48 compiles ~ speedup < 1).
    floor = 1.5 if quick else 3.0
    gate = (rec["speedup_vs_seed_path"] >= floor
            and rec["batched_compilations"] <= rec["policies"])
    _emit("sim/fig1_sweep", rec["batched_wall_s"] * 1e6 / rec["cells"],
          f"speedup_vs_seed={rec['speedup_vs_seed_path']}x;"
          f"compiles={rec['batched_compilations']}"
          f"(<= {rec['policies']} policies);"
          f"coll={rec['hlo']['collective_count']};"
          f"{'PASS' if gate else 'FAIL'}")

    gate = _policy_matrix_probe(results) and gate
    gate = _energy_probe(results) and gate
    gate = _keyshard_probe(results) and gate
    gate = _merged_exec_probe(results) and gate
    gate = _hist_tail_probe(results) and gate
    gate = _openloop_floor_gate(results) and gate

    if len(jax.devices()) < 2:
        # The sharded half of the gate cannot run — that is itself a gate
        # break (jax was imported before our 8-device virtualization, or
        # the caller pinned a single device): report it, don't skip it.
        results["sim/sharded_parity"] = {"devices": 1,
                                         "bit_identical": None}
        _emit("sim/sharded_parity", 0.0,
              "single device: sharded probe could not run;FAIL")
        return False
    from repro.launch.mesh import make_sweep_mesh
    cfg = sl.SimConfig(policy="libasl", sim_time_us=4_000.0)
    axes = {"slo_us": [30.0, 70.0], "seed": [0, 1, 2]}
    a, _ = sl.sweep(cfg, axes)
    b, _ = sl.sweep(cfg, axes, mesh=make_sweep_mesh())
    parity = all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    shard_rec = sl.sweep_log()[-1]
    results["sim/sharded_parity"] = {
        "devices": shard_rec["devices"], "bit_identical": parity,
        "collective_count": shard_rec["collectives"]["total_count"]}
    _emit("sim/sharded_parity", 0.0,
          f"devices={shard_rec['devices']};"
          f"bit_identical={parity};"
          f"coll={shard_rec['collectives']['total_count']}")
    return gate and parity


def _serving_section(results, quick: bool) -> bool:
    """CI gate for the serving stack (mirrors ``--section sim``): runs
    every serving bench, then gates on the db_serving rows — ASL must
    keep its TTFT P99 within ``SERVING_P99_FLOOR`` x its SLO, and FIFO
    must not beat ASL on token throughput.  Returns False on a break."""
    from benchmarks import serving_bench
    if quick:
        serving_bench.SCALE = 0.25
    _run_section("serving", serving_bench.ALL, results)
    by = {r["name"].split("/")[-1]: r
          for r in results["serving/db_serving"]}
    asl, fifo = by["asl"], by["fifo"]
    slo = asl["slo_ttft"]
    p99_ok = asl["ttft_p99"] <= SERVING_P99_FLOOR * slo
    tput_ok = asl["throughput_tok_s"] >= 0.95 * fifo["throughput_tok_s"]
    gate = bool(p99_ok and tput_ok)
    results["serving/gate"] = {
        "asl_ttft_p99": asl["ttft_p99"], "slo_ttft": slo,
        "p99_floor": SERVING_P99_FLOOR,
        "asl_tok_s": asl["throughput_tok_s"],
        "fifo_tok_s": fifo["throughput_tok_s"],
        "p99_ok": bool(p99_ok), "tput_ok": bool(tput_ok), "pass": gate}
    _emit("serving/gate", 0.0,
          f"asl_p99={asl['ttft_p99']:.2f}s(slo={slo:g}s,"
          f"floor={SERVING_P99_FLOOR:g}x);"
          f"asl_tok_s={asl['throughput_tok_s']:.0f}_vs_"
          f"fifo={fifo['throughput_tok_s']:.0f};"
          f"{'PASS' if gate else 'FAIL'}")
    return gate


SERVING_P99_FLOOR = 1.5


# Combined-fault probe load for --section chaos (docs/faults.md): lock-
# holder preemption + core churn + straggler spikes, all at once.
CHAOS_PROBE_KW = dict(preempt_rate=0.1, preempt_scale_us=30.0,
                      churn_rate=0.2, churn_period_us=200.0,
                      straggle_rate=0.05, straggle_scale=10.0)


def _chaos_section(results, quick: bool) -> bool:
    """CI gate for the fault-injection layer (docs/faults.md):

    1. liveness — every registered policy survives combined faults
       (preemption + churn + stragglers): every core keeps completing
       epochs, the sim reaches its horizon, the event budget holds;
    2. purity — a zero-rate cell of a gate-on faulted sweep is
       bit-identical to a plain fault-free run (fault injection off is
       provably a no-op);
    3. grace — the chaos_collapse figure's headline claim: LibASL's
       goodput under maximum preemption stays >= FIFO's.
    """
    import jax
    import numpy as np

    from benchmarks import paper_figs
    from repro.core import simlock as sl
    from repro.core.policies import REGISTRY

    horizon = 2_000.0 if quick else 10_000.0
    probe, live_ok = {}, True
    for name in sorted(REGISTRY):
        cfg = sl.SimConfig(policy=name, sim_time_us=horizon,
                           **CHAOS_PROBE_KW)
        st, grid = sl.sweep(cfg, {"seed": [0, 1]}, slo_us=60.0)
        cell_ok = True
        for s in sl.sweep_summaries(cfg, st, grid):
            cell_ok = (cell_ok
                       and min(s["epochs_per_core"]) > 0
                       and s["sim_time_us"] >= 0.9 * horizon
                       and s["events"] < cfg.max_events)
        probe[name] = {"ok": bool(cell_ok),
                       "summary": s}          # last cell, for the record
        live_ok = live_ok and cell_ok
    bad = [n for n, p in probe.items() if not p["ok"]]
    _emit("chaos/liveness", 0.0,
          f"policies={len(REGISTRY)};faults=preempt+churn+straggle;"
          + (f"stuck={','.join(bad)};" if bad else "")
          + ("PASS" if live_ok else "FAIL"))

    cfg = sl.SimConfig(policy="libasl", sim_time_us=horizon)
    st_sw, _ = sl.sweep(cfg, {"preempt_rate": [0.0, 0.1],
                              "churn_rate": [0.0, 0.2],
                              "straggle_rate": [0.0, 0.05]},
                        product=False, slo_us=60.0)
    st_plain = sl.run(cfg, 60.0, 0)
    zero_cell = jax.tree.map(lambda x: np.asarray(x[0]), st_sw)
    pure_ok = all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(zero_cell),
                        jax.tree.leaves(st_plain)))
    _emit("chaos/zero_rate_purity", 0.0,
          f"bit_identical={pure_ok};{'PASS' if pure_ok else 'FAIL'}")

    rows = paper_figs.chaos_collapse()
    results["chaos/chaos_collapse"] = rows
    mx = max(r["preempt_rate"] for r in rows)
    h = {r["policy"]: r for r in rows if r["preempt_rate"] == mx}
    grace_ok = h["libasl"]["goodput_eps"] >= h["fifo"]["goodput_eps"]
    _emit("chaos/goodput_gate", 0.0,
          f"pr{mx:g}:libasl_goodput={h['libasl']['goodput_eps']:.0f}"
          f"_vs_fifo={h['fifo']['goodput_eps']:.0f};"
          f"{'PASS' if grace_ok else 'FAIL'}")

    gate = bool(live_ok and pure_ok and grace_ok)
    results["chaos/gate"] = {
        "liveness": probe, "zero_rate_bit_identical": bool(pure_ok),
        "max_preempt_rate": float(mx),
        "libasl_goodput_eps": float(h["libasl"]["goodput_eps"]),
        "fifo_goodput_eps": float(h["fifo"]["goodput_eps"]),
        "pass": gate}
    return gate


def _roofline_section(results):
    art = Path(__file__).resolve().parents[1] / "artifacts" / "roofline"
    cells = []
    if art.exists():
        for f in sorted(art.glob("*.json")):
            d = json.loads(f.read_text())
            if d.get("ok") and not d.get("skipped"):
                cells.append(d)
                _emit(f"roofline/{d['cell']}",
                      max(d["t_compute_s"], d["t_memory_s"],
                          d["t_collective_s"]) * 1e6,
                      f"dom={d['dominant']};"
                      f"frac={d['roofline_fraction']:.2f};"
                      f"useful={d['useful_ratio']:.2f}")
    if not cells:
        _emit("roofline/missing", 0.0,
              "run: PYTHONPATH=src python -m benchmarks.roofline")
    results["roofline/cells"] = cells


SECTIONS = ("sim", "paper", "serving", "kernels", "roofline", "chaos")
# "sim" and "chaos" are opt-in (--section ...): "sim" mutates the XLA
# environment (8 virtual devices, pinned intra-op threading), which
# would silently change the kernel/serving baselines of a default
# all-sections run; "chaos" re-runs the chaos_collapse figure the paper
# section already produces.
DEFAULT_SECTIONS = ("paper", "serving", "kernels", "roofline")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--section", action="append", choices=SECTIONS,
                    default=None,
                    help="run only the given section(s); repeatable")
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: 0.1x simulator horizons so the "
                         "paper section fits in CI time")
    args = ap.parse_args(argv)
    sections = set(args.section or DEFAULT_SECTIONS)

    # The sim smoke gate probes the mesh-sharded sweep path: virtualize 8
    # host devices, and pin XLA's intra-op threading exactly as
    # benchmarks/simperf.py does (the three policy sweeps compile
    # concurrently; unpinned they thrash the container's 2 cores and the
    # speedup gate reads low).  Only effective before the first jax
    # import, so a caller-provided XLA_FLAGS wins.
    if "sim" in sections:
        from repro.launch.xla_flags import ensure_host_devices, prepend
        prepend("--xla_cpu_multi_thread_eigen=false",
                "intra_op_parallelism_threads=1")
        ensure_host_devices(8)

    # Repeated bench invocations (and CI re-runs on an unchanged image)
    # skip every XLA compile.
    enable_persistent_cache(ART.parent / "xla_cache")
    ART.mkdir(parents=True, exist_ok=True)
    results = {}
    from benchmarks import paper_figs
    if args.quick:
        paper_figs.SIM_SCALE = 0.1
    sim_ok = serving_ok = chaos_ok = True
    if "sim" in sections:
        sim_ok = _sim_section(results, args.quick)
    if "paper" in sections:
        _run_section("paper", paper_figs.ALL, results)
    if "serving" in sections:
        serving_ok = _serving_section(results, args.quick)
    if "kernels" in sections:
        _kernel_bench(results)
    if "roofline" in sections:
        _roofline_section(results)
    if "chaos" in sections:
        chaos_ok = _chaos_section(results, args.quick)
    # Merge into the existing file: a partial --section run must not
    # wipe the other sections' committed rows.
    out = ART / "results.json"
    if out.exists():
        try:
            prev = json.loads(out.read_text())
        except ValueError:
            prev = {}
        prev.update(results)
        results = prev
    out.write_text(json.dumps(results, indent=1, default=str))
    print(f"# wrote {out} ({len(results)} entries)")
    if not (sim_ok and serving_ok and chaos_ok):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
