"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall
microseconds per produced row; derived = the figure's headline metric) and
writes full JSON to artifacts/bench/results.json.

Sections:
  paper figures  — discrete-event AMP simulator (benchmarks/paper_figs.py)
  serving/fleet  — engine + dispatch + straggler sims (serving_bench.py)
  kernels        — per-kernel interpret-mode check vs jnp reference
  roofline       — reads artifacts/roofline/*.json (produced by
                   ``python -m benchmarks.roofline``; compile-heavy)
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks._jax_cache import enable_persistent_cache

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"


def _emit(name, us_per_call, derived):
    print(f"{name},{us_per_call:.3f},{derived}")


def _run_section(section: str, fns: dict, results: dict):
    for name, fn in fns.items():
        t0 = time.time()
        rows = fn()
        dt_us = (time.time() - t0) * 1e6
        results[f"{section}/{name}"] = rows
        derived = _headline(name, rows)
        _emit(f"{section}/{name}", dt_us / max(len(rows), 1), derived)


def _headline(name, rows) -> str:
    try:
        if name.startswith("fig1"):
            f4 = next(r for r in rows if r["policy"] == "fifo"
                      and r["n_threads"] == 4)
            f8 = next(r for r in rows if r["policy"] == "fifo"
                      and r["n_threads"] == 8)
            t8 = next(r for r in rows if r["policy"] == "tas"
                      and r["n_threads"] == 8)
            return (f"mcs_drop={1 - f8['tput'] / f4['tput']:.0%};"
                    f"tas_p99_vs_mcs={t8['p99_all'] / f8['p99_all']:.1f}x")
        if name.startswith("fig4"):
            f8 = next(r for r in rows if r["policy"] == "fifo"
                      and r["n_threads"] == 8)
            t8 = next(r for r in rows if r["policy"] == "tas"
                      and r["n_threads"] == 8)
            return (f"tas_tput_vs_mcs={t8['tput'] / f8['tput']:.2f}x;"
                    f"tas_p99_vs_mcs="
                    f"{t8['ep_p99_little'] / f8['ep_p99_little']:.1f}x")
        if name.startswith("fig5"):
            return ";".join(f"p{r['proportion']}:{r['tput']:.0f}/"
                            f"{r['ep_p99_little']:.0f}us" for r in rows)
        if name == "bench1_contended":
            mcs = next(r for r in rows if r["name"].endswith("mcs"))
            mx = next(r for r in rows if r["name"].endswith("MAX"))
            return f"libaslMAX_vs_mcs={mx['tput'] / mcs['tput']:.2f}x"
        if name == "bench1_slo_sweep":
            track = [abs(r["ep_p99_little"] - r["slo_us"]) / r["slo_us"]
                     for r in rows if 40 <= r["slo_us"] <= 300]
            return f"slo_tracking_err_med={np.median(track):.1%}"
        if name == "bench2_variable":
            ach = max(r["violation_excess"] for r in rows if r["achievable"])
            fell_back = rows[-1]["mean_window_us"] < 5.0
            return (f"achievable_excess={ach:.1%};"
                    f"impossible_phase_fell_back_to_fifo={fell_back}")
        if name == "bench3_mixed":
            return ";".join(f"{r['short_pct']}%:{r['tput_vs_mcs']:.2f}x"
                            for r in rows)
        if name == "bench4_scalability":
            mx = next(r for r in rows if "MAX" in r["name"]
                      and r["n_threads"] == 8)
            f4 = next(r for r in rows if r["policy"] == "fifo"
                      and r["n_threads"] == 4)
            return f"libaslMAX8_vs_mcs4={mx['tput'] / f4['tput']:.2f}x"
        if name == "bench5_contention":
            lo = rows[-1]
            hi = rows[0]
            return (f"low_contention_vs_mcs4={lo['speedup_vs_mcs4']:.2f}x;"
                    f"high_vs_mcs8={hi['speedup_vs_mcs8']:.2f}x")
        if name == "bench6_blocking":
            by = {(r["name"].split("/")[1], r["wakeup_us"]): r
                  for r in rows}
            mcs_deg = by[("mcs-park", 0.0)]["tput"] / \
                by[("mcs-park", 20.0)]["tput"]
            asl_deg = by[("libasl-block", 0.0)]["tput"] / \
                by[("libasl-block", 20.0)]["tput"]
            rel = by[("libasl-block", 20.0)]["tput"] / \
                by[("mcs-park", 20.0)]["tput"]
            return (f"wakeup20us:mcs_degrades={mcs_deg:.2f}x,"
                    f"libasl_degrades={asl_deg:.2f}x,"
                    f"libasl_vs_mcs={rel:.2f}x")
        if name == "db_serving":
            by = {r["name"].split("/")[-1]: r for r in rows}
            return (f"asl_ttft_p99={by['asl']['ttft_p99'] * 1e3:.0f}ms(viol"
                    f"={by['asl']['slo_violation_rate']:.0%});"
                    f"fifo_itl_p99={by['fifo']['itl_p99'] * 1e3:.0f}ms;"
                    f"asl_itl_p99={by['asl']['itl_p99'] * 1e3:.0f}ms")
        if name == "dispatch_fleet":
            lo = [r for r in rows if r["rate_rps"] == 10.0]
            hi = [r for r in rows if r["rate_rps"] == 48.0]
            g = {r["name"].split("/")[1]: r for r in lo}
            h = {r["name"].split("/")[1]: r for r in hi}
            return (f"low:asl_p99={g['asl']['p99'] * 1e3:.0f}ms_vs_fair="
                    f"{g['fair']['p99'] * 1e3:.0f}ms;"
                    f"high:asl_rps={h['asl']['throughput_rps']:.0f}_vs_"
                    f"fastonly={h['fast-only']['throughput_rps']:.0f}")
        if name == "straggler_training":
            by = {r["name"].split("/")[-1]: r for r in rows}
            return (f"asl_vs_sync={by['asl-staleness']['steps_per_s'] / by['sync']['steps_per_s']:.2f}x;"
                    f"p99_staleness={by['asl-staleness']['p99_staleness']:.0f}")
    except Exception as e:  # headline must never kill the run
        return f"(headline error: {e})"
    return ""


def _kernel_bench(results):
    """Interpret-mode kernel check + timing vs jnp reference."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, h, kh, s, dh = 1, 4, 2, 512, 64
    q = jax.random.normal(ks[0], (b, h, s, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, kh, s, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, kh, s, dh), jnp.float32)
    t0 = time.time()
    out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    jax.block_until_ready(out)
    dt = (time.time() - t0) * 1e6
    err = float(jnp.max(jnp.abs(
        out - ref.flash_attention_ref(q, k, v, causal=True))))
    results["kernels/flash_attention"] = {"err": err, "us": dt}
    _emit("kernels/flash_attention_interp", dt, f"max_err={err:.1e}")


def _roofline_section(results):
    art = Path(__file__).resolve().parents[1] / "artifacts" / "roofline"
    cells = []
    if art.exists():
        for f in sorted(art.glob("*.json")):
            d = json.loads(f.read_text())
            if d.get("ok") and not d.get("skipped"):
                cells.append(d)
                _emit(f"roofline/{d['cell']}",
                      max(d["t_compute_s"], d["t_memory_s"],
                          d["t_collective_s"]) * 1e6,
                      f"dom={d['dominant']};"
                      f"frac={d['roofline_fraction']:.2f};"
                      f"useful={d['useful_ratio']:.2f}")
    if not cells:
        _emit("roofline/missing", 0.0,
              "run: PYTHONPATH=src python -m benchmarks.roofline")
    results["roofline/cells"] = cells


SECTIONS = ("paper", "serving", "kernels", "roofline")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--section", action="append", choices=SECTIONS,
                    default=None,
                    help="run only the given section(s); repeatable")
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: 0.1x simulator horizons so the "
                         "paper section fits in CI time")
    args = ap.parse_args(argv)
    sections = set(args.section or SECTIONS)

    # Repeated bench invocations (and CI re-runs on an unchanged image)
    # skip every XLA compile.
    enable_persistent_cache(ART.parent / "xla_cache")
    ART.mkdir(parents=True, exist_ok=True)
    results = {}
    from benchmarks import paper_figs, serving_bench
    if args.quick:
        paper_figs.SIM_SCALE = 0.1
    if "paper" in sections:
        _run_section("paper", paper_figs.ALL, results)
    if "serving" in sections:
        _run_section("serving", serving_bench.ALL, results)
    if "kernels" in sections:
        _kernel_bench(results)
    if "roofline" in sections:
        _roofline_section(results)
    (ART / "results.json").write_text(json.dumps(results, indent=1,
                                                 default=str))
    print(f"# wrote {ART / 'results.json'}")


if __name__ == "__main__":
    main()
