"""Serving + straggler benchmarks (Table 1 / application-level analogues).

* ``db_serving`` — the five-database macro-benchmark analogue: a
  continuous-batching engine with mixed short/long requests (Get/Put-style
  bimodal service) under FIFO / greedy / ASL admission, at a load where the
  TTFT SLO is achievable only by bounded reordering.
* ``dispatch_fleet`` — heterogeneous replica fleet (big/little pods):
  fair round-robin vs fast-only vs ASL window spill across a load sweep
  (the paper's Figure 8g shape: slow replicas help at high load only).
* ``straggler_training`` — bounded-staleness DP vs synchronous under
  transient stragglers (the paper's ordering applied to gradient commits).
"""

from __future__ import annotations

import numpy as np

from repro.dist.staleness import BoundedStalenessController, simulate
from repro.serving.dispatch import DISPATCH_POLICIES, simulate_dispatch
from repro.serving.engine import CostModel, ServingEngine, poisson_workload
from repro.workloads import ClientClass, WorkloadMix
from repro.workloads.clients import metrics_by_class, multiclass_workload

# ---------------------------------------------------------------------------
# The ONE policy/load grid.  db_serving, dispatch_fleet, the serving CI
# gate (benchmarks/run.py --section serving) and the load-latency figure
# (benchmarks/paper_figs.loadlat_sweep) all read these tables — the grid
# used to be hard-coded separately in each.
# ---------------------------------------------------------------------------

ENGINE_POLICIES = (
    ("fifo", "fifo", {}),
    ("greedy", "greedy", {}),
    ("asl", "asl", dict(default_window=0.02, max_window=10.0)),
    ("asl-warm", "asl", dict(default_window=0.02, max_window=10.0,
                             warm_start=True, mi_factor=0.5)),
)
# DISPATCH_POLICIES is imported from repro.serving.dispatch — derived
# from the lock-policy registry (LockPolicy.host_dispatch), one naming
# scheme across the simulator, schedulers and fleet benches.
# Offered load as a fraction of fleet capacity; shared with the
# lock-level load-latency figure so both sweeps probe the same points.
LOAD_FRACS = (0.2, 0.4, 0.6, 0.8, 0.9)
# 4 fast replicas at 10 rps + 4 slow at 10/3 rps (service_s=0.1, 3x slow)
DISPATCH_CAPACITY_RPS = 4 / 0.1 + 4 / (0.1 * 3.0)

# Global duration scale: benchmarks/run.py --quick sets this < 1 so the
# serving smoke gate fits in CI time (mirrors paper_figs.SIM_SCALE).
SCALE = 1.0

DB_SLO_TTFT = 0.6


def db_serving(rate_rps=2.5, duration_s=150.0, slo_ttft=DB_SLO_TTFT):
    cost = CostModel(decode_step_s=2e-3, prefill_chunk_s=18e-3,
                     prefill_chunk=2048, max_batch=64)
    rows = []
    for name, sched, kw in ENGINE_POLICIES:
        eng = ServingEngine(sched, cost, scheduler_kwargs=kw, seed=1)
        poisson_workload(eng, rate_rps=rate_rps,
                         duration_s=duration_s * SCALE,
                         prompt_lens=[2048, 4096, 8192, 16384],
                         new_tokens=[32, 128, 256],
                         slo_ttft=slo_ttft, seed=2)
        m = eng.metrics()
        m.update(name=f"db_serving/{name}", slo_ttft=slo_ttft)
        rows.append(m)
    return rows


def db_multiclass(rate_rps=2.5, duration_s=150.0):
    """Fig 8c tenancy: a latency-critical and a best-effort class share
    one engine; ASL keeps one AIMD window per class (epoch_id)."""
    # No per-class ServiceSpec: engine replay derives all timing from
    # the CostModel + prompt_len/new_tokens columns (trace.service_s is
    # ignored on this path — see replay_workload).
    mix = WorkloadMix((
        ClientClass("latency-critical", weight=1.0, slo=0.4),
        ClientClass("best-effort", weight=1.0, slo=4.0),
    ))
    cost = CostModel(decode_step_s=2e-3, prefill_chunk_s=18e-3,
                     prefill_chunk=2048, max_batch=64)
    rows = []
    for name, sched, kw in ENGINE_POLICIES[:3]:        # fifo/greedy/asl
        eng = ServingEngine(sched, cost, scheduler_kwargs=kw, seed=1)
        multiclass_workload(eng, mix, rate_rps=rate_rps,
                            duration_s=duration_s * SCALE,
                            prompt_lens=[2048, 4096, 8192],
                            new_tokens=[32, 128], seed=2)
        per = metrics_by_class(eng, mix)
        row = dict(name=f"db_multiclass/{name}", by_class=per)
        for cls, m in per.items():
            for k, v in m.items():
                row[f"{cls}/{k}"] = v
        rows.append(row)
    return rows


def dispatch_fleet():
    rows = []
    for frac in LOAD_FRACS:
        rate = round(frac * DISPATCH_CAPACITY_RPS, 1)
        for pol in DISPATCH_POLICIES:
            m = simulate_dispatch(pol, rate_rps=rate, service_s=0.1,
                                  slo=0.5, duration_s=200.0 * SCALE,
                                  seed=3)
            m["name"] = f"dispatch/{pol}/load{frac:.2f}"
            m["rate_rps"] = rate
            m["load_frac"] = frac
            rows.append(m)
    return rows


def straggler_training():
    rows = []
    dur = [1.0] * 8
    kw = dict(straggle_prob=0.1, straggle_factor=5.0, seed=11,
              horizon_steps=300)
    for name, ctl, ckw in (
            ("sync", BoundedStalenessController(8, window_steps=0.0,
                                                max_window=0.0), {}),
            ("async-unbounded", BoundedStalenessController(
                8, window_steps=1e6, max_window=1e6),
             dict(quality_slo=float("inf"))),
            ("asl-staleness", BoundedStalenessController(
                8, window_steps=4.0, max_window=8.0),
             dict(quality_slo=6.0, penalty_per_stale=1.0))):
        sps, mean_st, p99_st = simulate(8, dur, controller=ctl, **kw, **ckw)
        rows.append(dict(name=f"straggler/{name}", steps_per_s=sps,
                         mean_staleness=mean_st, p99_staleness=p99_st))
    return rows


ALL = {
    "db_serving": db_serving,
    "db_multiclass": db_multiclass,
    "dispatch_fleet": dispatch_fleet,
    "straggler_training": straggler_training,
}
