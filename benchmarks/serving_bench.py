"""Serving + straggler benchmarks (Table 1 / application-level analogues).

* ``db_serving`` — the five-database macro-benchmark analogue: a
  continuous-batching engine with mixed short/long requests (Get/Put-style
  bimodal service) under FIFO / greedy / ASL admission, at a load where the
  TTFT SLO is achievable only by bounded reordering.
* ``dispatch_fleet`` — heterogeneous replica fleet (big/little pods):
  fair round-robin vs fast-only vs ASL window spill across a load sweep
  (the paper's Figure 8g shape: slow replicas help at high load only).
* ``straggler_training`` — bounded-staleness DP vs synchronous under
  transient stragglers (the paper's ordering applied to gradient commits).
"""

from __future__ import annotations

import numpy as np

from repro.dist.staleness import BoundedStalenessController, simulate
from repro.serving.dispatch import simulate_dispatch
from repro.serving.engine import CostModel, ServingEngine, poisson_workload


def db_serving(rate_rps=2.5, duration_s=150.0, slo_ttft=0.6):
    cost = CostModel(decode_step_s=2e-3, prefill_chunk_s=18e-3,
                     prefill_chunk=2048, max_batch=64)
    rows = []
    for name, sched, kw in (
            ("fifo", "fifo", {}),
            ("greedy", "greedy", {}),
            ("asl", "asl", dict(default_window=0.02, max_window=10.0)),
            ("asl-warm", "asl", dict(default_window=0.02, max_window=10.0,
                                     warm_start=True, mi_factor=0.5))):
        eng = ServingEngine(sched, cost, scheduler_kwargs=kw, seed=1)
        poisson_workload(eng, rate_rps=rate_rps, duration_s=duration_s,
                         prompt_lens=[2048, 4096, 8192, 16384],
                         new_tokens=[32, 128, 256],
                         slo_ttft=slo_ttft, seed=2)
        m = eng.metrics()
        m.update(name=f"db_serving/{name}", slo_ttft=slo_ttft)
        rows.append(m)
    return rows


def dispatch_fleet():
    rows = []
    for rate in (10.0, 20.0, 30.0, 40.0, 48.0):
        for pol in ("fair", "fast-only", "asl"):
            m = simulate_dispatch(pol, rate_rps=rate, service_s=0.1,
                                  slo=0.5, duration_s=200.0, seed=3)
            m["name"] = f"dispatch/{pol}/rate{rate:.0f}"
            m["rate_rps"] = rate
            rows.append(m)
    return rows


def straggler_training():
    rows = []
    dur = [1.0] * 8
    kw = dict(straggle_prob=0.1, straggle_factor=5.0, seed=11,
              horizon_steps=300)
    for name, ctl, ckw in (
            ("sync", BoundedStalenessController(8, window_steps=0.0,
                                                max_window=0.0), {}),
            ("async-unbounded", BoundedStalenessController(
                8, window_steps=1e6, max_window=1e6),
             dict(quality_slo=float("inf"))),
            ("asl-staleness", BoundedStalenessController(
                8, window_steps=4.0, max_window=8.0),
             dict(quality_slo=6.0, penalty_per_stale=1.0))):
        sps, mean_st, p99_st = simulate(8, dur, controller=ctl, **kw, **ckw)
        rows.append(dict(name=f"straggler/{name}", steps_per_s=sps,
                         mean_staleness=mean_st, p99_staleness=p99_st))
    return rows


ALL = {
    "db_serving": db_serving,
    "dispatch_fleet": dispatch_fleet,
    "straggler_training": straggler_training,
}
