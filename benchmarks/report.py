"""Render EXPERIMENTS.md from artifacts (dryrun / roofline / bench JSONs).

Run after the sweeps:
    PYTHONPATH=src python -m benchmarks.roofline
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.report
"""

from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
ART = ROOT / "artifacts"


def _load_dir(d):
    out = {}
    if (ART / d).exists():
        for f in sorted((ART / d).glob("*.json")):
            out[f.stem] = json.loads(f.read_text())
    return out


def _fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.1f}s"
    return f"{x * 1e3:.2f}ms"


def dryrun_table(cells):
    lines = ["| cell | mesh | status | compile | peak GiB/dev | HLO coll GiB/dev |",
             "|---|---|---|---|---|---|"]
    for cid, d in sorted(cells.items()):
        if d.get("skipped"):
            lines.append(f"| {cid} | {d.get('mesh','')} | SKIP ({d['reason']}) | | | |")
            continue
        peak = d.get("memory", {}).get("peak_memory_in_bytes", 0) / 2 ** 30
        coll = d.get("collectives", {}).get("total", {}).get("link_bytes", 0) / 2 ** 30
        st = "ok" if d["ok"] else f"FAIL: {d.get('error', '')[:60]}"
        lines.append(f"| {cid} | {d.get('mesh','')} | {st} | "
                     f"{d.get('compile_s','')}s | {peak:.2f} | {coll:.2f} |")
    return "\n".join(lines)


def roofline_table(cells, baseline):
    lines = ["| cell | compute | memory | collective | dominant | bound "
             "(=max) | roofline frac | useful | vs baseline bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for cid, d in sorted(cells.items()):
        if d.get("skipped") or not d.get("ok"):
            continue
        b = baseline.get(cid)
        bound = max(d["t_compute_s"], d["t_memory_s"], d["t_collective_s"])
        speed = ""
        if b and b.get("ok") and not b.get("skipped"):
            bb = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
            speed = f"{bb / bound:.2f}x"
        lines.append(
            f"| {cid} | {_fmt_s(d['t_compute_s'])} | "
            f"{_fmt_s(d['t_memory_s'])} | {_fmt_s(d['t_collective_s'])} | "
            f"{d['dominant']} | {_fmt_s(bound)} | "
            f"{d['roofline_fraction']:.3f} | {d['useful_ratio']:.2f} | "
            f"{speed} |")
    return "\n".join(lines)


def bench_lines():
    f = ART / "bench" / "results.json"
    if not f.exists():
        return "(run `python -m benchmarks.run` first)"
    return "see `artifacts/bench/results.json` + `bench_output.txt` CSV"


def simbench_table():
    """Per-figure table from BENCH_simlock.json.  Device-bound figures
    record ``events_per_s``; host-bound aggregate figures (bench2/3/5)
    record ``rows_per_s`` — both shapes render here."""
    f = ROOT / "BENCH_simlock.json"
    if not f.exists():
        return "(run `python -m benchmarks.simperf` first)"
    rec = json.loads(f.read_text())
    lines = ["| figure | rows | wall | compilations | throughput |",
             "|---|---|---|---|---|"]
    for name, d in rec.get("figures", {}).items():
        if d.get("events_per_s"):
            tput = f"{d['events_per_s']:,} events/s"
        elif d.get("rows_per_s"):
            tput = f"{d['rows_per_s']:g} rows/s (host)"
        else:
            tput = "-"
        lines.append(f"| {name} | {d['rows']} | {_fmt_s(d['wall_s'])} | "
                     f"{d.get('compilations', '-')} | {tput} |")
    return "\n".join(lines)


HEADER = """# EXPERIMENTS — Asymmetry-aware Scalable Locking on a multi-pod JAX framework

Everything below is produced by checked-in code; regenerate with
`python -m benchmarks.report` after the sweeps listed in its docstring.

Hardware model (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI per chip. This container is CPU-only: all numbers are
derived from **compiled artifacts** (`.lower().compile()`), the discrete-
event simulator, or host-clock microbenchmarks — never from fake TPU
timings.

## §Paper-validation (the faithful reproduction, before any beyond-paper work)

The lock-level experiments run on the deterministic discrete-event AMP
simulator (`repro/core/simlock.py`; 4 big + 4 little cores, 3.75x CS gap /
1.8x NOP gap, calibration in `benchmarks/paper_figs.py`). Paper claim vs
reproduction (full rows in `artifacts/bench/results.json`):

| paper claim | reproduction |
|---|---|
| MCS throughput collapses >50% scaling 4 big -> 8 cores (Fig 1) | 55% drop |
| TAS (little-affinity) P99 ~6.2x MCS (Fig 1) | 11.8x (same failure mode, harsher calibration) |
| TAS (big-affinity) higher tput but latency collapse (Fig 4) | 1.68x tput, 8.3x little-core P99 |
| static proportion trades tput vs latency on a curve, no SLO control (Fig 5) | monotone: p1..p50 => 140k..316k CS/s vs 48..636us P99 |
| LibASL falls back to FIFO at unachievable SLO (LibASL-0) | tput == MCS +-5%, windows -> 0 |
| LibASL P99 sticks to the SLO line while tput grows (Fig 8b) | little-core P99 tracks SLO (median err ~20% across the sweep, tightening with epochs) |
| LibASL-MAX ~1.7x MCS in the contended microbenchmark (Fig 8a) | 1.71x |
| LibASL-MAX throughput "does not drop at all" as little threads join (Fig 8e) | 1.00x vs 4-big-core MCS at 8 threads |
| window re-converges across load shifts; impossible load => FIFO (Fig 8d) | achievable phases stay under SLO; x256 phase windows collapse (fallback) |
| heterogeneous epochs keep SLO (Fig 8c) | P99 <= SLO at all short/long mixes; tput up to 1.4x MCS |
| little cores help at low contention (Fig 8g / Bench-5) | LibASL 1.54x vs big-only at low contention, 1.64x vs MCS-8 at high |
| blocking locks: FIFO pays wakeup per handoff (Bench-6) | FIFO degrades faster with wakeup cost; simulator has no OS scheduler, so the paper's full 96% spin-then-park gap is out of scope (documented model limit) |

### Simulator bench (BENCH_simlock.json)

Wall clock, compilation count and throughput per checked-in figure run
(`python -m benchmarks.simperf`). The merged multi-policy figures
(loadlat/openloop/bench1) compile fewer executables than policies.

{SIMBENCH}

The threaded lock implementations (Algorithms 1-3 verbatim) are separately
tested for mutual exclusion, FIFO order, bounded reordering and AIMD
algebra (`tests/test_core_locks.py`) and are used for real inside the data
pipeline, checkpoint manager and serving queue.

### The technique at datacenter scale (DESIGN.md §3 mapping)

* serving admission (`db_serving`): ASL keeps TTFT P99 at/below the SLO
  while matching FIFO token throughput; greedy (TAS analogue) starves
  prefill outright. Beyond-paper `asl-warm` (window warm-start +
  multiplicative increase) removes most of the AIMD convergence transient.
* heterogeneous replica fleet (`dispatch_fleet`): fair dispatch inflates
  P99 ~2.7x at low load (slow replicas on the critical path = Implication
  1); fast-only collapses at high load (the paper's strawman); the ASL
  window spills to slow replicas exactly as much as the SLO allows.
* bounded-staleness DP (`straggler_training`): +31% steps/s over
  synchronous under 10%/5x transient stragglers with P99 staleness bounded
  at the window — the lock's starvation-freedom argument, verbatim.

## §Dry-run

Every applicable (arch x shape) cell lowered **and compiled** for the
single-pod 16x16 mesh and the 2x16x16 multi-pod mesh (512 placeholder
host devices; the 'pod' axis shards the batch). 0 failures. Skips follow
DESIGN.md §5 (encoder-only decode; quadratic archs at 500k).

Caveats on the reported numbers: `memory_analysis()` on the CPU backend
lacks the TPU buffer-assignment passes, so `peak` is indicative (donated
args alias; temp is pessimistic); collective GiB in this table counts
scan bodies ONCE (the roofline section corrects for that).

{DRYRUN}

## §Roofline

Per-device terms from compiled artifacts (method in
`benchmarks/roofline.py`): depth finite-difference over unrolled shallow
variants (XLA cost analysis counts loop bodies once — measured, see
DESIGN.md), attention q-block scan unrolled for exact FLOPs, microbatch
weight-regather traffic measured at M=2 and scaled. `memory` uses the
closed-form HBM traffic model (HLO bytes-accessed double-counts fused
traffic; both are in the JSON artifacts). `useful` =
MODEL_FLOPS / (HLO_FLOPs x 256) — <1.0 reflects remat recompute, fp32
loss math, and attention-vs-6ND accounting. xlstm compute/memory terms are
analytic (its time-step scan stays rolled; collectives measured).
`roofline frac` = compute / max(terms): 1.0 means compute-bound.

"vs baseline bound" compares against the pre-optimization snapshot
(`artifacts/roofline_baseline/`, the paper-faithful-but-naive first
implementation) on the step-time lower bound max(terms).

{ROOFLINE}

### Reading the table

* **train/prefill cells are collective-bound at TP=16**: with 1M-token
  steps, Megatron-style per-layer activation all-reduces dominate; that is
  the true cost of the fixed (data=16, model=16) mesh for <=13B models
  (production would pick TP<=4 for those; the mesh is fixed by the
  assignment, the remaining gap is overlappable in a real pipeline).
* **decode cells are memory-bound after the §Perf fixes** — the intrinsic
  bound (weights + KV cache read per token) — i.e. at the decode roofline.
* **long_500k** runs only on the sub-quadratic archs and is memory-bound
  on tiny state: recurrentgemma reads a 2048-slot ring + constant RG-LRU
  state; xlstm reads constant matrix memory. That is the architectural
  point of those cells.

## §Perf — hypothesis -> change -> measure log

Three cells hillclimbed per the assignment: the worst roofline fraction
(llama3-405b/train_4k, frac 0.045), the most collective-bound
(grok-1-314b/decode_32k, collective/compute = 757x), and the cell most
representative of the paper's technique (llama3-405b/decode_32k — the
engine-slot step the ASL scheduler admits work into).

### Cell 1: llama3-405b / train_4k  (1449s -> 294s bound, 4.9x)

| it | hypothesis (napkin) | change | dominant term before -> after | verdict |
|---|---|---|---|---|
| 1 | The grouped-GQA einsum (`reshape H->(K,g)`) splits the sharded head axis across two dims; GSPMD warns "involuntary full rematerialization" and all-gathers fp32 scores: 3 x 128 GiB x 126 layers ~= 47 TiB ~= most of the 66 TiB gap | head-major attention: `_expand_kv` + single-head-dim einsums (`bthd,bshd->bhts`) | collective 1449s -> 494s (-66%) | CONFIRMED (warnings gone; score gathers eliminated) |
| 2 | Cross-shard partial sums ride fp32 because the bf16 cast sits after the dot; emitting bf16 halves TP all-reduce bytes (~2x on the ~40GiB/layer AR traffic) | `ein()` emits compute dtype (MXU still accumulates fp32 in-shard) | collective 494s -> 417s (-16%) | PARTIAL — fwd ARs halved; fp32 persists on norm-backward cotangent paths (XLA hoists converts) |
| 3a | RoPE's fp32 internals are what the seq->head all-to-all reshards (2 x 8 GiB/layer); casting the halves pre-concat halves it | cast before concat in `rope()` | (measured together with 3b) | CONFIRMED in op dump |
| 3b | Seq-parallel residuals shard remat saves 16x => activation memory allows M=16 -> 4; per-micro FSDP weight re-gather + grad RS scale with M: save ~ (16-4) x 126 x 5.7 GiB | `train_microbatches=4` for llama3-405b | collective 417s -> 294s (-30%); memory 3.7s -> 1.4s | CONFIRMED |

| 4 | The llama3/grok *prefill* cells regressed ~14% after it-1 (57.5s -> 67.1s): suspected cause was the explicit sharding constraint on the expanded KV forcing H-sized reshards | drop the constraint, let GSPMD propagate the q-side sharding into the repeat | collective 67.05s -> 67.05s (no change) | REFUTED — the constraint was not the mechanism; the head-major form itself costs ~14% extra prefill collectives on the two largest-GQA archs, accepted against the 2.3-4.9x train and 22-28x decode wins (root cause — expanded-KV seq gathers — tracked) |

Remaining gap to compute-bound (294s vs 67s): fp32 cotangent ARs through
the norm paths and AR->reduce-scatter pattern-match misses; both are
overlappable comm in a real schedule and tracked as future work.
Roofline fraction 0.045 -> 0.227.  A refuted hypothesis is recorded above
per the methodology — it localized the prefill regression to the einsum
form rather than the constraint.

### Cell 2: grok-1-314b / decode_32k  (1.5s -> 54ms bound, 28x)

| it | hypothesis (napkin) | change | result | verdict |
|---|---|---|---|---|
| 1 | FSDP layout re-gathers 'data'-sharded weights EVERY token step: 628 GB bf16 / 16 (TP) ~= 39 GB/step -> ~0.8s at 50 GB/s, matching the measured 1.48s with MoE overheads | **weight-stationary decode**: batch replicates, residual d_model shards over 'data'; every matmul contracts against stationary 2D-sharded weights; only KB..MB activation psums move; KV cache keeps batch x seq sharding; MoE routes replicated (tiny at q_len=1) and computes against stationary experts | collective 1483ms -> 22ms (66x); step bound 1483ms -> 54ms (28x), now **memory-dominant** (weights+cache reads = the intrinsic decode roofline) | CONFIRMED |

### Cell 3: llama3-405b / decode_32k  (1.9s -> 72ms bound, 26x)

Same change as cell 2 (the fix is a rules-table property, not per-arch):
collective 1894ms -> 35ms (54x); bound now the 72ms memory term =
810 GB bf16 weights / 256 chips + 4.2 GB/dev cache at 819 GB/s — the
serving engine's slot cost the ASL scheduler admits against.  At
per-token step bounds this cell went from ~0.5 tok/s/seq to ~14
tok/s/seq equivalents.

### Iteration 5 (refuted): expert parallelism for phi3.5-moe / train_4k

Hypothesis: sharding the 16 experts over the 16-way data axis (EP;
`expert_parallel=True`, GSPMD inserts the dispatch/combine all-to-alls)
removes the per-layer FSDP expert-weight gathers. Napkin check *before
believing it*: the expert weights are small (16 x 3 x 4096 x 6400 x 2B /
16 TP ~= 157 MB/layer gathered) while the dispatch buffers carry the full
1M-token batch (~20 GB/layer each way). Measured: per-layer link bytes
32.6 GiB -> 45.6 GiB (+40%). REFUTED — EP pays only when experts are large
relative to the token batch (the grok regime at small batch), not here;
`expert_parallel` stays off by default but remains a config flag with the
measurement harness in place.

### Whole-table effect of the hillclimb changes

The three fixes are framework-level (attention formulation, collective
dtype, decode weight layout), so the *entire* 40-cell baseline moved, not
just the three target cells — see the "vs baseline bound" column: every
decode cell improved 3.4x-27.7x (all now at the memory roofline), grok
train 2.3x, qwen train 4.1x, llama3 train 4.9x; two prefill cells paid
~14% (iteration 4).

### Beyond-paper (scheduler level)

The paper-faithful ASL scheduler is the baseline; the beyond-paper
variants are opt-in flags measured in `db_serving`:

* `warm_start`: initialize the class window from the first observed
  latency headroom instead of the paper's fixed default;
* `mi_factor`: multiplicative window growth while latency < 0.5 x SLO
  (the paper grows only linearly), cutting re-convergence time after load
  drops.

Both preserve the violation->halve response (the paper's safety
property); see `serving/db_serving` rows (asl vs asl-warm).

## Reproduction notes / threats to validity

* 1 physical CPU core: lock wall-clock scaling is simulated (DESIGN.md
  §2); threaded implementations are correctness-tested only.
* XLA cost model counts while bodies once — handled by unrolled-shallow
  finite differences; verified on a 10-step scan (10.0x flops ratio).
* CPU-backend `memory_analysis` lacks TPU buffer assignment; peak numbers
  are indicative, the analytic memory model is documented in
  `repro/dist/hlo_analysis.py`.
* The roofline assumes no compute/communication overlap (terms are
  reported separately so any overlap assumption can be applied on top).
"""


def main():
    dry = _load_dir("dryrun")
    roof = _load_dir("roofline")
    base = _load_dir("roofline_baseline")
    doc = HEADER.format(DRYRUN=dryrun_table(dry),
                        ROOFLINE=roofline_table(roof, base),
                        SIMBENCH=simbench_table())
    (ROOT / "EXPERIMENTS.md").write_text(doc)
    print(f"wrote {ROOT / 'EXPERIMENTS.md'} "
          f"({len(dry)} dryrun cells, {len(roof)} roofline cells)")


if __name__ == "__main__":
    main()
