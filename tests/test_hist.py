"""Streaming-histogram layer (cfg.hist; repro.core.stats) + the ring
warmup fixes.

Covers the ISSUE-10 guarantees: merge associativity/commutativity
(cell / shard / device orders agree bitwise), gate-off purity, the
EPCAP-exceeding acceptance run (histogram P99 within one bucket's
relative error of an exact large-cap reference at constant SimState
memory), the ``tail_truncated`` flag, the ``_ring_values`` warmup
off-by-one regression, and the shared nan-on-empty percentile helper's
call sites.  Per-policy quantile-vs-exact conformance lives in
tests/test_policies.py.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import simlock as sl
from repro.core import stats
from repro.core.policies.base import US

SLO_US = 80.0


def _cfg(**kw):
    kw.setdefault("policy", "libasl")
    kw.setdefault("sim_time_us", 3_000.0)
    return sl.SimConfig(hist=True, **kw)


# ---------------------------------------------------------------------------
# stats unit behavior
# ---------------------------------------------------------------------------

def test_percentile_empty_is_nan_not_raise():
    assert np.isnan(stats.percentile([], 99))
    assert np.isnan(stats.percentile(np.zeros(0), 50))
    assert stats.percentile([3.0], 99) == 3.0


def test_quantile_empty_is_nan():
    assert np.isnan(stats.quantile(np.zeros(64, np.uint32), 99, 1.0, 1e4))


def test_quantile_within_bound_of_exact():
    rng = np.random.default_rng(7)
    v = rng.lognormal(3.0, 1.0, 100_000)
    lo, hi, b = 0.1, 1e6, 512
    log2lo, invlog2g = stats.layout(lo, hi, b)
    idx = np.clip(1 + np.floor((np.log2(v) - log2lo)
                               * invlog2g).astype(int), 0, b - 1)
    h = np.bincount(idx, minlength=b)
    bound = stats.rel_err_bound(lo, hi, b)
    for q in (50.0, 99.0, 99.9):
        exact = np.percentile(v, q)
        est = stats.quantile(h, q, lo, hi)
        assert abs(est - exact) <= bound * exact


def test_good_count_tracks_exact():
    rng = np.random.default_rng(11)
    v = rng.lognormal(2.0, 0.8, 50_000)
    lo, hi, b = 0.1, 1e5, 512
    log2lo, invlog2g = stats.layout(lo, hi, b)
    idx = np.clip(1 + np.floor((np.log2(v) - log2lo)
                               * invlog2g).astype(int), 0, b - 1)
    h = np.bincount(idx, minlength=b)
    for thr in (2.0, 10.0, 50.0):
        exact = int((v <= thr).sum())
        est = stats.good_count(h, thr, lo, hi)
        # off by at most one bucket's contents
        edge = np.searchsorted(stats.edges(lo, hi, b), thr)
        assert abs(est - exact) <= h[edge] + 1


def test_merge_orders_agree_bitwise():
    """cell + shard + device merge orders are all plain u64 sums —
    bitwise identical no matter the grouping or ordering."""
    rng = np.random.default_rng(3)
    hists = rng.integers(0, 2**31, (12, 64)).astype(np.uint32)
    flat = stats.merge(hists)
    by_cell = stats.merge([stats.merge(hists[i::3]) for i in range(3)])
    by_shard = stats.merge([stats.merge(hists[i:i + 4])
                            for i in (8, 0, 4)])
    reversed_ = stats.merge(hists[::-1])
    for other in (by_cell, by_shard, reversed_):
        np.testing.assert_array_equal(flat, other)
    assert flat.dtype == np.uint64


# ---------------------------------------------------------------------------
# _ring_values warmup regression (satellite 1)
# ---------------------------------------------------------------------------

def test_ring_values_low_count_is_empty():
    """cnt <= warmup must yield ZERO samples — the old slice kept
    exactly one warmup-contaminated sample."""
    buf = np.arange(100, dtype=np.float32)
    for cnt in (0, 1, 31, 32):
        assert sl._ring_values(buf, cnt, warmup=32).size == 0
    got = sl._ring_values(buf, 33, warmup=32)
    np.testing.assert_array_equal(got, [32.0])


def test_ring_values_unwrapped_unchanged_past_warmup():
    buf = np.arange(100, dtype=np.float32)
    np.testing.assert_array_equal(sl._ring_values(buf, 50, warmup=32),
                                  np.arange(32, 50, dtype=np.float32))


def test_ring_values_wrapped_trims_surviving_warmup():
    """Ring wrapped but not far enough to evict all warmup samples:
    the survivors must be trimmed (samples cap..cnt are kept)."""
    cap, cnt, warmup = 100, 110, 32
    buf = np.empty(cap, np.float32)
    for i in range(cnt):           # sample i has value i
        buf[i % cap] = i
    got = sl._ring_values(buf, cnt, warmup)
    # samples 10..109 survive in the ring; 10..31 are still warmup
    np.testing.assert_array_equal(got, np.arange(32, 110, dtype=np.float32))


def test_ring_values_wrapped_past_warmup_keeps_all():
    cap, cnt = 100, 500
    buf = np.arange(cap, dtype=np.float32)
    assert sl._ring_values(buf, cnt, 32).size == cap


# ---------------------------------------------------------------------------
# Engine: gate-off purity, EPCAP-exceeding acceptance, truncation flag
# ---------------------------------------------------------------------------

def test_gate_off_summary_has_no_hist_keys():
    cfg = sl.SimConfig(policy="libasl", sim_time_us=2_000.0)
    s = sl.summarize(cfg, sl.run(cfg, SLO_US, seed=3), slo_us=SLO_US)
    assert not any("hist" in k for k in s)
    assert "tail_truncated" not in s


def test_epcap_exceeded_reports_histogram_tail():
    """The acceptance run: >= 32x cap epochs through a tiny ring.  The
    wrapped run's primary ep_p99_all_us must come from the histogram
    and land within one bucket's relative error of an exact large-cap
    reference — while its SimState latency memory stays constant."""
    small = _cfg(epcap=64, sim_time_us=40_000.0)
    large = dataclasses.replace(small, epcap=8192)
    st_s = sl.run(small, SLO_US, seed=3)
    st_l = sl.run(large, SLO_US, seed=3)
    total = int(np.asarray(st_s.ep_cnt).sum())
    assert total >= 32 * small.epcap
    # ring size never feeds back into the dynamics: same trajectory
    np.testing.assert_array_equal(np.asarray(st_s.ep_cnt),
                                  np.asarray(st_l.ep_cnt))
    np.testing.assert_array_equal(np.asarray(st_s.ep_hist),
                                  np.asarray(st_l.ep_hist))
    s_small = sl.summarize(small, st_s, slo_us=SLO_US)
    s_large = sl.summarize(large, st_l, slo_us=SLO_US)
    assert s_small.get("tail_truncated") is True
    assert "tail_truncated" not in s_large
    bound = s_small["hist_rel_err_bound"]
    exact = s_large["ep_p99_all_us"]          # un-wrapped: ring-exact
    got = s_small["ep_p99_all_us"]            # wrapped: histogram-backed
    assert got == s_small["ep_p99_hist_all_us"]
    assert abs(got - exact) <= bound * exact
    # constant memory: latency state is epcap-shaped rings + fixed hists
    assert st_s.ep_lat.shape == (8, 64)
    assert st_s.ep_hist.shape == st_l.ep_hist.shape == (8, 512)
    # goodput switches to the full-history histogram fraction too
    assert s_small["slo_good_frac"] == s_small["slo_good_frac_hist"]
    assert abs(s_small["slo_good_frac"] - s_large["slo_good_frac"]) < 0.05


def test_summarize_goodput_and_percentiles_share_samples():
    """Satellite 2: one collection pass — a core whose count sits at or
    below warmup contributes to NEITHER metric (the old second pass
    could disagree with the percentile pass)."""
    cfg = sl.SimConfig(policy="fifo", sim_time_us=2_000.0)
    st = sl.run(cfg, SLO_US, seed=3)
    n = cfg.n_cores
    ep_lat = np.asarray(st.ep_lat)[:n]
    ep_cnt = np.asarray(st.ep_cnt)[:n]
    vals = np.concatenate([sl._ring_values(ep_lat[c], int(ep_cnt[c]), 32)
                           for c in range(n)]) / US
    s = sl.summarize(cfg, st, slo_us=SLO_US)
    assert s["slo_good_frac"] == float(np.mean(vals <= SLO_US))
    assert s["ep_p99_all_us"] == stats.percentile(vals, 99)


# ---------------------------------------------------------------------------
# Merging across sweep cells, shards and devices
# ---------------------------------------------------------------------------

def test_sweep_cells_merge_matches_single_runs():
    """Per-cell histograms from one batched executable merge (sum) to
    exactly the union of the dedicated single runs' histograms."""
    cfg = _cfg()
    st, grid = sl.sweep(cfg, {"seed": [0, 3, 5]}, slo_us=SLO_US)
    singles = [np.asarray(sl.run(cfg, SLO_US, seed=s).ep_hist)
               for s in (0, 3, 5)]
    np.testing.assert_array_equal(
        stats.merge(np.asarray(st.ep_hist)),
        stats.merge([stats.merge(h) for h in singles]))


def test_sharded_hist_bit_parity():
    """Sharding the cell axis must not move one histogram count."""
    from repro.launch.mesh import make_sweep_mesh
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 (virtual) device")
    cfg = _cfg()
    axes = {"seed": [0, 1, 2]}
    a, _ = sl.sweep(cfg, axes, slo_us=SLO_US)
    b, _ = sl.sweep(cfg, axes, slo_us=SLO_US, mesh=make_sweep_mesh())
    np.testing.assert_array_equal(np.asarray(a.ep_hist),
                                  np.asarray(b.ep_hist))
    np.testing.assert_array_equal(np.asarray(a.cs_hist),
                                  np.asarray(b.cs_hist))


def test_fleet_tail_merges_everything():
    cfg = _cfg()
    st, _ = sl.sweep(cfg, {"seed": [0, 3]}, slo_us=SLO_US)
    fleet = sl.fleet_tail(cfg, st, slo_us=SLO_US)
    merged = stats.merge(np.asarray(st.ep_hist))
    lo_t, hi_t = cfg.hist_lo_us * US, cfg.hist_hi_us * US
    want = stats.quantile(merged, 99, lo_t, hi_t) / US
    assert fleet["ep_p99_hist_all_us"] == pytest.approx(want, rel=1e-6)
    assert 0.0 <= fleet["slo_good_frac_hist"] <= 1.0
    with pytest.raises(ValueError):
        sl.fleet_tail(sl.SimConfig(), st)


def test_hist_axes_share_one_executable():
    """Bucket range and warmup ride traced: configs differing only in
    them (and gate-off bucket counts) must share the jit key."""
    a = sl._canon(_cfg())
    b = sl._canon(_cfg(hist_lo_us=0.5, hist_hi_us=1e5, hist_warmup=7))
    assert a == b
    off_a = sl._canon(sl.SimConfig(policy="libasl"))
    off_b = sl._canon(sl.SimConfig(policy="libasl", hist_buckets=64))
    assert off_a == off_b
    # but the gate bit and the gate-on bucket count ARE the jit key
    assert sl._canon(_cfg()) != sl._canon(_cfg(hist_buckets=64))
    assert off_a != a


def test_hist_config_validation():
    with pytest.raises(ValueError):
        sl.SimConfig(hist_buckets=2)
    with pytest.raises(ValueError):
        sl.SimConfig(hist_lo_us=0.0)
    with pytest.raises(ValueError):
        sl.SimConfig(hist_lo_us=10.0, hist_hi_us=1.0)
    with pytest.raises(ValueError):
        sl.SimConfig(hist_warmup=-1)


# ---------------------------------------------------------------------------
# Satellite 3: nan-on-empty at the external call sites
# ---------------------------------------------------------------------------

def test_staleness_zero_commits_reports_nan():
    from repro.dist.staleness import BoundedStalenessController, simulate
    ctl = BoundedStalenessController(2, window_steps=1.0)
    sps, mean_st, p99_st = simulate(2, [1.0, 1.0], controller=ctl,
                                    horizon_steps=0)
    assert sps == 0.0
    assert np.isnan(mean_st) and np.isnan(p99_st)


def test_engine_metrics_no_itl_samples_is_nan():
    from repro.serving.engine import Request, ServingEngine
    eng = ServingEngine()
    # one completed request, zero decode intervals: ttft is real but the
    # ITL distribution is empty -> nan, not the old 0.0 sentinel
    eng.done.append(Request(rid=0, arrival_t=0.0, prompt_len=8,
                            max_new_tokens=1, slo_ttft=1.0,
                            first_token_t=0.5, finish_t=0.5, generated=1))
    m = eng.metrics(warmup_frac=0.0)
    assert m["n"] == 1 and m["ttft_p50"] == 0.5
    assert np.isnan(m["itl_p50"]) and np.isnan(m["itl_p99"])


def test_clients_and_dispatch_use_shared_helper():
    from repro.serving import dispatch as dsp
    from repro.serving import engine as eng
    from repro.workloads import clients as cl
    assert cl.stats is stats and dsp.stats is stats and eng.stats is stats


def test_dispatch_empty_latencies_report_nan():
    from repro.serving.dispatch import simulate_dispatch
    # duration too short for any arrival: zero completions
    res = simulate_dispatch("fair", duration_s=1e-9, rate_rps=1.0,
                            slo=1.0, seed=0)
    assert res["n"] == 0
    assert np.isnan(res["p50"]) and np.isnan(res["p99"])
    assert np.isnan(res["slo_violation"])
