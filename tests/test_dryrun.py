"""End-to-end dry-run at container scale: the same build_rules ->
lower -> compile -> accounting path as the 512-device production dry-run,
on the 8 virtual host devices the test session provides (conftest).
Previously `repro/launch/dryrun.py` only ever ran at production mesh
sizes and was unexercised here (ROADMAP open item)."""

import json

import jax
import pytest

from repro.launch import dryrun
from repro.launch.mesh import make_mesh


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 (virtual) devices")
    return make_mesh((4, 2), ("data", "model"))


def test_dryrun_cell_end_to_end_tiny(mesh, tmp_path, monkeypatch):
    monkeypatch.setattr(dryrun, "ARTIFACTS", tmp_path)
    rec = dryrun.run_cell("yi-6b", "train_4k", mesh=mesh, tiny=True,
                          force=True)
    assert rec["ok"], rec.get("error")
    assert rec["devices"] == 8
    assert rec["mesh"] == "4x2"
    # a real data+tensor-parallel train step must communicate:
    # gradient all-reduces over data, activation reduces over model
    assert rec["collectives"]["total_count"] > 0
    assert rec["collectives"]["all-reduce"]["count"] > 0
    assert rec["collectives"]["total_bytes"] > 0
    assert rec["flops"] > 0
    assert rec["model_flops"] > 0
    # the record round-trips through the artifact file (incremental skip)
    on_disk = json.loads(
        (tmp_path / "yi-6b__train_4k__mesh4x2_tiny.json").read_text())
    assert on_disk["collectives"] == rec["collectives"]
    again = dryrun.run_cell("yi-6b", "train_4k", mesh=mesh, tiny=True)
    assert again["ok"] and again["cell"] == rec["cell"]


def test_dryrun_decode_cell_weight_stationary(mesh, tmp_path, monkeypatch):
    """Decode runs weight-stationary (batch replicated): the cell must
    still compile and account on the small mesh."""
    monkeypatch.setattr(dryrun, "ARTIFACTS", tmp_path)
    rec = dryrun.run_cell("yi-6b", "decode_32k", mesh=mesh, tiny=True,
                          force=True)
    assert rec["ok"], rec.get("error")
    assert rec["collectives"]["total_count"] > 0
