"""Correctness tests for the threaded lock implementations (Algorithms 1-3).

Wall-clock scaling is not measurable on one core; these tests prove the
structural contracts: mutual exclusion, FIFO handoff order, bounded
reordering (window expiry forces enqueue), proportional batching ratio,
AIMD window algebra, epoch nesting.
"""

import threading
import time

import pytest

from repro.core import (AIMDWindow, ASLMutex, FIFOLock, LibASL,
                        ProportionalLock, ReorderableLock, TASLock,
                        TicketLock)
from repro.core.aimd import aimd_update


def _hammer(lock, n_threads=8, n_iter=200):
    """Shared counter increments; returns (final, expected, interleave_ok)."""
    state = {"x": 0}

    def worker():
        for _ in range(n_iter):
            lock.acquire()
            v = state["x"]
            time.sleep(0)  # force interleaving opportunity
            state["x"] = v + 1
            lock.release()

    ts = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return state["x"], n_threads * n_iter


@pytest.mark.parametrize("mk", [FIFOLock, TASLock, TicketLock,
                                lambda: ProportionalLock(lambda: True),
                                lambda: ReorderableLockAdapter()])
def test_mutual_exclusion(mk):
    lock = mk()
    got, want = _hammer(lock)
    assert got == want


class ReorderableLockAdapter:
    """Exercise lock_reorder/lock_immediately mixed under contention."""

    def __init__(self):
        self._rl = ReorderableLock()
        self._i = 0

    def acquire(self):
        self._i += 1
        if self._i % 2:
            self._rl.lock_immediately()
        else:
            self._rl.lock_reorder(50_000)  # 50us window

    def release(self):
        self._rl.unlock()


def test_fifo_handoff_order():
    lock = FIFOLock()
    order = []
    lock.lock_fifo()  # hold so the workers queue up
    started = threading.Barrier(5)
    ready = []

    def worker(i):
        started.wait()
        # serialize queue entry by index
        while len(ready) != i:
            time.sleep(1e-4)
        ready.append(i)
        lock.lock_fifo()
        order.append(i)
        lock.unlock_fifo()

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in ts:
        t.start()
    started.wait()
    while len(ready) < 4:
        time.sleep(1e-3)
    time.sleep(0.02)  # let the last worker enqueue
    lock.unlock_fifo()
    for t in ts:
        t.join()
    assert order == [0, 1, 2, 3]


def test_reorder_window_bounds_bypass():
    """A standby competitor enqueues after its window; once queued it cannot
    be bypassed (bounded reordering => starvation freedom)."""
    rl = ReorderableLock()
    rl.lock_immediately()          # hold
    acquired = []

    def standby():
        rl.lock_reorder(window_ns=20_000_000)  # 20 ms
        acquired.append("standby")
        rl.unlock()

    t = threading.Thread(target=standby)
    t.start()
    time.sleep(0.05)               # > window: standby must be enqueued now
    # A late immediate competitor must NOT overtake the expired standby.
    def big():
        rl.lock_immediately()
        acquired.append("big")
        rl.unlock()

    t2 = threading.Thread(target=big)
    t2.start()
    time.sleep(0.01)
    rl.unlock()
    t.join(); t2.join()
    assert acquired[0] == "standby"


def test_reorder_fast_path_free_lock():
    rl = ReorderableLock()
    t0 = time.monotonic()
    rl.lock_reorder(window_ns=int(1e9))  # free lock: no wait
    assert time.monotonic() - t0 < 0.2
    rl.unlock()


class _CountingFIFO:
    """FIFO stub recording the poll/enqueue sequence (no real blocking)."""

    def __init__(self, free=False):
        self.free = free
        self.polls = 0
        self.locks = 0

    def is_lock_free(self):
        self.polls += 1
        return self.free

    def lock_fifo(self):
        self.locks += 1

    def unlock_fifo(self):
        pass


def test_zero_window_standby_enqueues_immediately():
    """Regression: a window fully collapsed by AIMD (<= 0) must skip the
    standby loop entirely — straight to lock_fifo, zero free-lock polls,
    no monotonic_ns comparison spinning."""
    for w in (0.0, -5.0):
        fifo = _CountingFIFO(free=False)
        rl = ReorderableLock(fifo)
        rl.lock_reorder(window_ns=w)
        assert fifo.locks == 1
        assert fifo.polls == 0


def test_positive_window_still_polls_before_enqueue():
    """The zero-window short-circuit must not swallow the standby phase:
    with a real window the free-lock fast path still runs."""
    fifo = _CountingFIFO(free=True)
    rl = ReorderableLock(fifo)
    rl.lock_reorder(window_ns=1000.0)
    assert fifo.locks == 1
    assert fifo.polls >= 1


def test_proportional_ratio():
    """1 little grant after every N big grants (paper Figure 5 policy)."""
    role = threading.local()
    lock = ProportionalLock(lambda: getattr(role, "big", False),
                            proportion=3)
    grants = []
    lock.acquire()  # hold while everyone queues

    def worker(big, tag):
        role.big = big
        lock.acquire()
        grants.append(tag)
        time.sleep(0.001)
        lock.release()

    ts = []
    for i in range(6):
        ts.append(threading.Thread(target=worker, args=(True, f"B{i}")))
    for i in range(2):
        ts.append(threading.Thread(target=worker, args=(False, f"L{i}")))
    for t in ts:
        t.start()
    time.sleep(0.05)
    role.big = True
    lock.release()
    for t in ts:
        t.join()
    # First little-core grant must come after exactly 3 bigs
    first_l = next(i for i, g in enumerate(grants) if g.startswith("L"))
    assert first_l == 3, grants


# ---------------------------------------------------------------------------
# AIMD (Algorithm 2)
# ---------------------------------------------------------------------------

def test_aimd_violation_halves_and_unit_rescaled():
    w = AIMDWindow(window=1000.0, unit=10.0, pct=99.0)
    w.update(latency=500.0, slo=100.0)  # violated
    # halve -> 500, unit = 500*0.01 = 5, then +unit
    assert w.window == pytest.approx(505.0)
    assert w.unit == pytest.approx(5.0)


def test_aimd_linear_growth():
    w = AIMDWindow(window=100.0, unit=7.0, pct=99.0)
    for _ in range(5):
        w.update(latency=1.0, slo=100.0)
    assert w.window == pytest.approx(100.0 + 5 * 7.0)


def test_aimd_cap():
    w = AIMDWindow(window=100.0, unit=1e12, max_window=500.0)
    w.update(1.0, 100.0)
    assert w.window == 500.0


def test_aimd_jnp_matches_host():
    import numpy as np
    w, u = 1000.0, 10.0
    host = AIMDWindow(window=w, unit=u, pct=99.0, max_window=1e9)
    for lat, slo in [(50, 100), (150, 100), (99, 100), (1e4, 100), (1, 100)]:
        host.update(lat, slo)
        w, u = aimd_update(w, u, float(lat), float(slo), pct=99.0,
                           max_window=1e9)
    assert np.asarray(w) == pytest.approx(host.window, rel=1e-6)
    assert np.asarray(u) == pytest.approx(host.unit, rel=1e-6)


# ---------------------------------------------------------------------------
# LibASL epoch API (Algorithm 3)
# ---------------------------------------------------------------------------

def test_epoch_nesting_and_window_selection():
    clock = {"t": 0}
    rt = LibASL(is_big_core=lambda: False, clock_ns=lambda: clock["t"])
    rt.epoch_start(1)
    rt.epoch_start(2)           # nested: inner epoch governs
    w2 = rt.current_window_ns()
    clock["t"] += 10_000
    rt.epoch_end(2, slo_ns=5_000)   # violated: inner window halves
    assert rt._tls.cur_epoch_id == 1
    rt.epoch_start(2)
    assert rt.current_window_ns() < w2
    clock["t"] += 1
    rt.epoch_end(2, slo_ns=5_000)
    clock["t"] += 1
    rt.epoch_end(1, slo_ns=100_000)
    assert rt._tls.cur_epoch_id == -1


def test_epoch_end_without_start_raises_not_zero_latency():
    """Regression: epoch_end with no matching epoch_start used to measure
    ~0 latency (never-violated) and grow the AIMD window from a bogus
    sample; it must refuse instead."""
    clock = {"t": 0}
    rt = LibASL(is_big_core=lambda: False, clock_ns=lambda: clock["t"])
    with pytest.raises(RuntimeError):
        rt.epoch_end(3, slo_ns=100.0)
    # a completed epoch cannot be ended twice either
    rt.epoch_start(3)
    clock["t"] += 50
    rt.epoch_end(3, slo_ns=100.0)
    with pytest.raises(RuntimeError):
        rt.epoch_end(3, slo_ns=100.0)


def test_epoch_end_mismatched_nesting_keeps_inner_governing():
    """Ending an outer epoch while an inner one is open removes the outer
    from the nesting stack; the inner epoch stays current and the later
    inner end unwinds cleanly to the remaining stack."""
    clock = {"t": 0}
    rt = LibASL(is_big_core=lambda: False, clock_ns=lambda: clock["t"])
    rt.epoch_start(1)
    rt.epoch_start(2)
    rt.epoch_start(3)                 # stack [1, 2], current 3
    clock["t"] += 10
    rt.epoch_end(2, slo_ns=1000.0)    # outer end out of order
    assert rt._tls.cur_epoch_id == 3  # inner still governs
    assert rt._tls.stack == [1]
    clock["t"] += 10
    rt.epoch_end(3, slo_ns=1000.0)
    assert rt._tls.cur_epoch_id == 1
    clock["t"] += 10
    rt.epoch_end(1, slo_ns=1000.0)
    assert rt._tls.cur_epoch_id == -1
    assert rt._tls.stack == []


def test_epoch_reentrant_same_id_balanced():
    """Reentrant same-id nesting: per-id start timestamps stack LIFO, so
    the inner end measures the inner start and the balanced outer end
    measures the outer one (not a bogus re-used slot / raise)."""
    clock = {"t": 0}
    rt = LibASL(is_big_core=lambda: False, clock_ns=lambda: clock["t"])
    rt.epoch_start(5)             # outer at t=0
    clock["t"] = 100
    rt.epoch_start(5)             # inner at t=100
    clock["t"] = 130
    assert rt.epoch_end(5, slo_ns=1e9) == 30    # inner: 130-100
    clock["t"] = 150
    assert rt.epoch_end(5, slo_ns=1e9) == 150   # outer: 150-0
    assert rt._tls.cur_epoch_id == -1
    assert rt._tls.stack == [] and rt._tls.starts == {}


def test_big_core_skips_adjustment():
    clock = {"t": 0}
    rt = LibASL(is_big_core=lambda: True, clock_ns=lambda: clock["t"])
    rt.epoch_start(7)
    w0 = rt._tls.epochs[7].window
    clock["t"] += 10 ** 9
    rt.epoch_end(7, slo_ns=1)   # hugely violated but big core: no change
    assert rt._tls.epochs[7].window == w0


def test_asl_mutex_dispatch():
    role = threading.local()
    rt = LibASL(is_big_core=lambda: getattr(role, "big", True))
    m = rt.mutex()
    role.big = True
    with m:
        pass
    role.big = False
    rt.epoch_start(1)
    with m:
        pass
    rt.epoch_end(1, slo_ns=10 ** 9)
    got, want = _hammer(m, n_threads=4, n_iter=100)
    assert got == want
