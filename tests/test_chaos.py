"""Chaos-layer tests: FaultSpec/SimConfig validation, the host fault
schedules, and timeout/retry/admission-control in the serving engine +
dispatch fleet (docs/faults.md).

Device-sim fault conformance (parity, liveness, zero-rate purity) lives
in tests/test_policies.py; sweep resume in tests/test_sweep.py.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import simlock as sl
from repro.faults import FaultSpec, host as flt_host
from repro.serving.dispatch import simulate_dispatch
from repro.serving.engine import CostModel, Request, ServingEngine

# ---------------------------------------------------------------------------
# FaultSpec validation
# ---------------------------------------------------------------------------


def test_faultspec_defaults_inactive():
    assert not FaultSpec().active
    assert FaultSpec(preempt_rate=0.1).active
    assert FaultSpec(churn_rate=0.1).active
    assert FaultSpec(straggle_rate=0.1).active


@pytest.mark.parametrize("kw", [
    dict(preempt_rate=-0.1), dict(preempt_rate=1.5),
    dict(churn_rate=2.0), dict(straggle_rate=float("nan")),
    dict(preempt_scale=-1.0), dict(churn_period=0.0),
    dict(straggle_scale=0.5),
])
def test_faultspec_rejects_bad_values(kw):
    with pytest.raises(ValueError):
        FaultSpec(**kw)


# ---------------------------------------------------------------------------
# SimConfig validation (construction-time, not trace-time)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(preempt_rate=-0.1), dict(preempt_rate=1.5),
    dict(churn_rate=float("nan")), dict(churn_period_us=0.0),
    dict(straggle_scale=0.5), dict(sim_time_us=-1.0),
    dict(sim_time_us=float("nan")), dict(n_cores=0),
    dict(seg_cs_us=(3.0, 1.0)),              # length != seg_lock's
    dict(seg_noncrit_us=(-1.0,)), dict(wl_rate=0.0),
    dict(fault_mask=(float("nan"),) * 8),
])
def test_simconfig_rejects_bad_values(kw):
    with pytest.raises(ValueError):
        sl.SimConfig(policy="fifo", **kw)


def test_simconfig_unknown_policy_suggests():
    with pytest.raises(ValueError, match="libasl"):
        sl.SimConfig(policy="libasal")
    with pytest.raises(ValueError, match="unknown lock policy"):
        sl.SimConfig(policy="zzz-not-a-policy")


# ---------------------------------------------------------------------------
# Host fault schedules (repro.faults.host): counter-pure + zero-rate off
# ---------------------------------------------------------------------------

def test_outage_mask_deterministic_and_zero_off():
    spec = FaultSpec(churn_rate=0.4, churn_period=1.0)
    a = flt_host.outage_mask(spec, 4, 30.0, seed=7)
    b = flt_host.outage_mask(spec, 4, 30.0, seed=7)
    np.testing.assert_array_equal(a, b)
    assert a.any() and not a.all()
    off = flt_host.outage_mask(FaultSpec(), 4, 30.0, seed=7)
    assert not off.any()


def test_stalls_and_spikes_zero_rate_are_silent():
    assert not flt_host.spike_hits(FaultSpec(), 0, 64, seed=0).any()
    assert (flt_host.preempt_stalls(FaultSpec(), 0, 64, seed=0) == 0).all()
    spec = FaultSpec(preempt_rate=0.5, preempt_scale=0.1,
                     straggle_rate=0.5)
    assert flt_host.spike_hits(spec, 0, 256, seed=0).any()
    st = flt_host.preempt_stalls(spec, 0, 256, seed=0)
    assert (st >= 0).all() and st.max() > 0


# ---------------------------------------------------------------------------
# Serving engine: timeout / retry / backoff / admission / goodput
# ---------------------------------------------------------------------------

def _slow_prefill_cost():
    # One chunk = 0.5s of clock: requests with >1 chunk left are easy to
    # expire against a sub-second timeout.
    return CostModel(prefill_chunk_s=0.5, prefill_chunk=512,
                     decode_step_s=1e-3)


def test_engine_defaults_have_inert_counters():
    eng = ServingEngine("fifo", _slow_prefill_cost())
    for _ in range(4):
        eng.submit(512, 4, slo_ttft=10.0)
    eng.run(until_done=4)
    m = eng.metrics(warmup_frac=0.0)
    assert m["timeouts_total"] == 0
    assert m["retries_total"] == 0
    assert m["drops_total"] == 0
    assert m["n"] == 4 and m["goodput_frac"] == 1.0


def test_engine_timeout_and_retry_counters():
    eng = ServingEngine("fifo", _slow_prefill_cost(),
                        timeout_s=0.4, max_retries=1)
    for _ in range(6):                    # 2 chunks each: 1s of prefill
        eng.submit(1024, 2, slo_ttft=10.0)
    eng.run(until_t=8.0)
    m = eng.metrics(warmup_frac=0.0)
    assert m["timeouts_total"] > 0
    assert m["retries_total"] > 0
    # every request either finished or exhausted its retries
    assert len(eng.done) + len(eng.expired) == 6
    assert all(r.timed_out for r in eng.expired)


def test_engine_retry_backoff_is_capped_exponential():
    eng = ServingEngine("fifo", timeout_s=1.0, max_retries=10,
                        backoff_base_s=0.1, backoff_cap_s=0.4)
    r = Request(0, 0.0, 512, 1, 1.0)
    dues = []
    for _ in range(5):
        eng._on_timeout(r)
        dues.append(eng._retry_q[-1][0] - eng.clock)
        eng._retry_q.clear()
    assert dues == [0.1, 0.2, 0.4, 0.4, 0.4]


def test_engine_admission_control_sheds():
    eng = ServingEngine("fifo", _slow_prefill_cost(), admit_limit=2)
    rs = [eng.submit(512, 2, slo_ttft=10.0) for _ in range(5)]
    m = eng.metrics(warmup_frac=0.0)
    assert m["drops_total"] == 3
    assert [r.dropped for r in rs] == [False, False, True, True, True]
    assert len(eng.shed) == 3
    eng.run(until_done=2)
    assert len(eng.done) == 2


def test_engine_goodput_counts_shed_and_expired_against():
    eng = ServingEngine("fifo", _slow_prefill_cost(),
                        timeout_s=0.6, admit_limit=2)
    eng.submit(512, 2, slo_ttft=10.0)     # 1 chunk: completes in time
    eng.submit(2048, 2, slo_ttft=10.0)    # 4 chunks: expires
    for _ in range(4):
        eng.submit(512, 2, slo_ttft=10.0)   # past the limit: shed
    eng.run(until_t=8.0)
    m = eng.metrics(warmup_frac=0.0)
    assert m["n"] == 1 and len(eng.shed) == 4 and len(eng.expired) == 1
    # 1 good completion out of 6 offered: shed + expired count against
    assert m["goodput_frac"] == pytest.approx(1 / 6)
    assert m["goodput_req_s"] > 0.0


# ---------------------------------------------------------------------------
# Dispatch fleet chaos
# ---------------------------------------------------------------------------

def test_dispatch_zero_chaos_counters_inert():
    m = simulate_dispatch("asl", duration_s=20.0, slo=0.6, seed=1)
    assert m["timeouts"] == m["retries"] == m["drops"] == m["lost"] == 0
    assert m["goodput_rps"] is not None
    m2 = simulate_dispatch("asl", duration_s=20.0, slo=0.6, seed=1,
                           faults=FaultSpec())
    assert m == m2                      # inactive FaultSpec is a no-op


def test_dispatch_timeout_retry_admission():
    m = simulate_dispatch("asl", duration_s=20.0, slo=0.6, seed=1,
                          rate_rps=150.0, timeout_s=0.4, max_retries=2)
    assert m["timeouts"] > 0 and m["retries"] > 0 and m["lost"] > 0
    assert m["goodput_rps"] <= m["throughput_rps"]
    m2 = simulate_dispatch("asl", duration_s=20.0, slo=0.6, seed=1,
                           rate_rps=150.0, admit_cap=10)
    assert m2["drops"] > 0


def test_dispatch_faults_degrade_tail():
    f = FaultSpec(churn_rate=0.3, churn_period=2.0, straggle_rate=0.1,
                  straggle_scale=5.0, preempt_rate=0.05, preempt_scale=0.5)
    base = simulate_dispatch("fair", duration_s=30.0, slo=0.6, seed=3)
    chaos = simulate_dispatch("fair", duration_s=30.0, slo=0.6, seed=3,
                              faults=f)
    assert chaos["p99"] > base["p99"]
    assert chaos["completed"] > 0       # no deadlock under churn


def test_dispatch_full_chaos_terminates():
    f = FaultSpec(churn_rate=0.5, churn_period=1.0, preempt_rate=0.2,
                  preempt_scale=1.0, straggle_rate=0.2, straggle_scale=8.0)
    m = simulate_dispatch("asl", duration_s=20.0, slo=0.6, seed=0,
                          timeout_s=1.0, max_retries=3, admit_cap=100,
                          faults=f)
    assert m["completed"] > 0
