"""Fused device event loop tests (docs/simulator.md §Fused step kernel
& multi-policy executables):

* Pallas masked-step kernel (``repro/kernels/simstep.py``, interpret
  mode on CPU) — exact bit-parity vs the jnp reference across every
  registered policy for single runs, batched sweeps and open-loop
  workloads, plus chunk-size invariance;
* merged multi-policy executable (``cfg.policy_set``) — golden-digest
  parity per member against ``tests/data/keyshard_golden.json``, the
  one-executable discipline, config validation, and the 1e4-cell batch
  capacity probe.
"""

import dataclasses
import json

import jax
import numpy as np
import pytest

import golden_digests as gd
from repro.core import simlock as sl
from repro.core.policies import REGISTRY

GOLDEN = json.loads(gd.GOLDEN.read_text())


def _assert_states_equal(a, b, ctx=""):
    fa, fb = a._asdict(), b._asdict()
    assert sorted(fa) == sorted(fb)
    for name in fa:
        if name == "pol":
            for k in fa[name]:
                np.testing.assert_array_equal(
                    np.asarray(fa[name][k]), np.asarray(fb[name][k]),
                    f"{ctx}pol.{k}")
        else:
            np.testing.assert_array_equal(
                np.asarray(fa[name]), np.asarray(fb[name]),
                f"{ctx}{name}")


def _pallas(cfg):
    return dataclasses.replace(cfg, use_pallas=True)


# ---------------------------------------------------------------------------
# Pallas kernel bit-parity (interpret mode on this CPU container)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", sorted(REGISTRY))
def test_pallas_single_parity(policy):
    """use_pallas=True is bit-identical to the jnp lowering for a single
    run of every registered policy — the kernel evaluates the engine's
    own _step jaxpr, so ANY divergence is a packing/unpacking bug."""
    cfg = sl.SimConfig(policy=policy, sim_time_us=gd.SIM_US)
    _assert_states_equal(sl.run(cfg, gd.SLO_US, seed=gd.SEED),
                         sl.run(_pallas(cfg), gd.SLO_US, seed=gd.SEED),
                         f"{policy}/")


@pytest.mark.parametrize("policy", ["fifo", "libasl", "ks_crew"])
def test_pallas_sweep_parity(policy):
    """The vmapped (masked-step) lowering of the kernel matches the jnp
    sweep bit-for-bit on the golden sweep shape."""
    cfg = sl.SimConfig(policy=policy, sim_time_us=gd.SIM_US)
    a, _ = sl.sweep(cfg, dict(gd.SWEEP_AXES), slo_us=gd.SLO_US,
                    seed=gd.SEED)
    b, _ = sl.sweep(_pallas(cfg), dict(gd.SWEEP_AXES), slo_us=gd.SLO_US,
                    seed=gd.SEED)
    _assert_states_equal(a, b, f"{policy}/sweep/")


@pytest.mark.parametrize("policy", ["fifo", "shfl"])
def test_pallas_openloop_parity(policy):
    """Open-loop workloads add the ARRIVAL handler to the dispatch
    table — the kernel must retire that path bit-identically too."""
    cfg = sl.SimConfig(policy=policy, wl=True, wl_open=True,
                       wl_process="poisson", wl_rate=0.8,
                       sim_time_us=gd.SIM_US)
    _assert_states_equal(sl.run(cfg, gd.SLO_US, seed=gd.SEED),
                         sl.run(_pallas(cfg), gd.SLO_US, seed=gd.SEED),
                         f"{policy}/open/")


def test_pallas_chunk_invariance():
    """The live-guard makes a fixed-size chunk safe: different chunk
    sizes retire different partial tails but identical final states —
    on the Pallas path exactly as on the jnp path."""
    base = sl.SimConfig(policy="libasl", sim_time_us=gd.SIM_US,
                        use_pallas=True)
    ref = sl.run(base, gd.SLO_US, seed=gd.SEED)
    for chunk in (32, 128):
        got = sl.run(dataclasses.replace(base, chunk=chunk),
                     gd.SLO_US, seed=gd.SEED)
        _assert_states_equal(ref, got, f"chunk{chunk}/")


def test_pallas_digest_parity_sample():
    """Spot-check the Pallas path against the PRE-refactor golden
    digests directly (not just the current jnp path)."""
    for policy in ("fifo", "dvfs_race"):
        cfg = sl.SimConfig(policy=policy, sim_time_us=gd.SIM_US,
                           use_pallas=True)
        dig = gd.digest_state(sl.run(cfg, gd.SLO_US, seed=gd.SEED))
        for field, h in GOLDEN[policy]["single"].items():
            assert dig.get(field) == h, (policy, field)


# ---------------------------------------------------------------------------
# Merged multi-policy executable
# ---------------------------------------------------------------------------

def test_merged_golden_digest_parity():
    """ONE merged executable over the whole registry reproduces every
    policy's golden sweep digests bit-for-bit: the policy axis rides
    product-major, so cells [4i:4i+4] are policy i's golden sweep grid
    in the golden capture's own cell order."""
    names = sorted(GOLDEN)
    cfg = sl.SimConfig(policy=names[0], policy_set=tuple(names),
                       sim_time_us=gd.SIM_US)
    axes = {"policy": names}
    axes.update(gd.SWEEP_AXES)
    n0 = sl.n_batch_executables()
    st, _ = sl.sweep(cfg, axes, slo_us=gd.SLO_US, seed=gd.SEED)
    assert sl.n_batch_executables() - n0 <= 1
    per = 1
    for v in gd.SWEEP_AXES.values():
        per *= len(v)
    for i, name in enumerate(names):
        cell = jax.tree.map(lambda x, i=i: x[i * per:(i + 1) * per], st)
        dig = gd.digest_state(cell)
        for field, h in GOLDEN[name]["sweep"].items():
            assert dig.get(field) == h, (name, field)


def test_merged_policy_set_validation():
    with pytest.raises(ValueError, match="policy_set"):
        sl.SimConfig(policy="fifo", policy_set=("fifo", "nope"))
    with pytest.raises(ValueError, match="policy_set"):
        sl.SimConfig(policy="fifo", policy_set=("fifo", "fifo"))
    with pytest.raises(ValueError, match="policy_set"):
        sl.SimConfig(policy="edf", policy_set=("fifo", "tas"))
    with pytest.raises(ValueError, match="policy"):
        sl.sweep(sl.SimConfig(policy="fifo", sim_time_us=500.0),
                 {"policy": []})


def test_merged_policy_sweep_matches_singles():
    """Each cell of a merged policy x slo sweep is bit-identical to the
    same cell from the policy's OWN single-policy executable (the
    fully-conditional-handler contract, end to end)."""
    names = ("fifo", "tas", "libasl", "ks_crew")
    cfg = sl.SimConfig(policy="fifo", policy_set=names,
                       sim_time_us=2_000.0)
    axes = {"policy": [], "slo_us": []}
    for n in names:
        for s in (40.0, 90.0):
            axes["policy"].append(n)
            axes["slo_us"].append(s)
    st, _ = sl.sweep(cfg, axes, product=False, seed=gd.SEED)
    i = 0
    for n in names:
        one = sl.SimConfig(policy=n, sim_time_us=2_000.0)
        for s in (40.0, 90.0):
            want, _ = sl.sweep(one, {"slo_us": [s]}, seed=gd.SEED)
            cell = jax.tree.map(lambda x, i=i: x[i:i + 1], st)
            for f in ("t", "events", "phase", "t_ready", "ep_cnt",
                      "cs_cnt", "ep_lat", "window", "cur_rw"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(want, f)),
                    np.asarray(getattr(cell, f)), (n, s, f))
            i += 1


def test_merged_batch_capacity_1e4_cells():
    """1e4+ cells in ONE merged executable: 4 policies x 2500 seeds with
    a small latency ring.  Every lane must retire events."""
    names = ("fifo", "tas", "prop", "libasl")
    cfg = sl.SimConfig(policy="fifo", policy_set=names, epcap=64,
                       sim_time_us=60.0)
    axes = {"policy": [], "seed": []}
    for n in names:
        for s in range(2500):
            axes["policy"].append(n)
            axes["seed"].append(s)
    n0 = sl.n_batch_executables()
    st, _ = sl.sweep(cfg, axes, slo_us=gd.SLO_US, product=False)
    assert sl.n_batch_executables() - n0 <= 1
    ev = np.asarray(st.events)
    assert ev.shape == (10_000,)
    assert (ev > 0).all()


def test_horizon_axis_matches_config():
    """A swept sim_time_us cell is bit-identical to a single run whose
    config carries that horizon (the traced-horizon plumbing)."""
    cfg = sl.SimConfig(policy="libasl", sim_time_us=4_000.0)
    st, _ = sl.sweep(cfg, {"sim_time_us": [1_000.0, 4_000.0]},
                     slo_us=gd.SLO_US, seed=gd.SEED)
    for i, t in enumerate((1_000.0, 4_000.0)):
        single = sl.run(dataclasses.replace(cfg, sim_time_us=t),
                        gd.SLO_US, seed=gd.SEED)
        cell = jax.tree.map(lambda x, i=i: x[i], st)
        _assert_states_equal(single, cell, f"T{t}/")
