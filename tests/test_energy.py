"""Energy/DVFS layer tests (repro.core.energy, docs/energy.md).

The contract: with no power tables set the energy accumulator is
statically compiled out and every SimState/summary leaf is bit-identical
to a pre-energy run; with tables set, ``SimState.energy`` is exactly the
time integral of the phase power (telescoping sum over event steps), so
a uniform 1 W draw conserves energy_j == active-cores x sim-seconds.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import energy
from repro.core import simlock as sl
from repro.core.policies import REGISTRY


def _cfg(policy="fifo", **kw):
    kw.setdefault("sim_time_us", 4_000.0)
    return sl.SimConfig(policy=policy, **kw)


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


ALL_POLICIES = tuple(REGISTRY)


# ---------------------------------------------------------------------------
# Purity: the energy layer off (or zero) must not perturb anything
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_zero_power_bit_identical(policy):
    """Gate-on with all-zero power tables == gate-off, every leaf
    (0 + 0*dt accumulates exact f32 zeros)."""
    base = _cfg(policy, straggle_rate=0.1,
                fault_mask=(1.0, 1.0, 0.0, 1.0) * 2)
    zero = sl.with_columns(base, p_cs=(0.0,) * 8, p_spin=(0.0,) * 8,
                           p_park=(0.0,) * 8, p_idle=(0.0,) * 8)
    assert sl._canon(base) != sl._canon(zero)   # gate IS in the jit key
    _leaves_equal(sl.run(base, 200.0, seed=2), sl.run(zero, 200.0, seed=2))


def test_default_dvfs_bit_identical():
    """dvfs=1.0 everywhere is bitwise x/1.0 == x on the host-built
    durations — identical tables, identical run."""
    base = _cfg("shfl")
    one = sl.with_columns(base, dvfs=(1.0,) * 8)
    _leaves_equal(sl.build_tables(base), sl.build_tables(one))
    _leaves_equal(sl.run(base, 200.0), sl.run(one, 200.0))


def test_summarize_without_power_has_zero_energy():
    cfg = _cfg()
    s = sl.summarize(cfg, jax.tree.map(np.asarray, sl.run(cfg, 200.0)))
    assert s["energy_j"] == 0.0
    assert "tput_per_watt" not in s and "edp" not in s


# ---------------------------------------------------------------------------
# Conservation + the power model
# ---------------------------------------------------------------------------

def test_energy_conservation_uniform_power():
    """1 W in every phase, DVFS off: energy == integral of 1 W over the
    horizon for each active core (Sum dt telescopes to t_end)."""
    cfg = sl.with_columns(_cfg("fifo", sim_time_us=10_000.0),
                          p_cs=(1.0,) * 8, p_spin=(1.0,) * 8,
                          p_park=(1.0,) * 8, p_idle=(1.0,) * 8)
    st = sl.run(cfg, 1e9)
    s = sl.summarize(cfg, jax.tree.map(np.asarray, st))
    want = cfg.n_cores * cfg.sim_time_us * 1e-6        # n x seconds
    np.testing.assert_allclose(s["energy_j"], want, rtol=0.02)
    assert s["power_w"] == pytest.approx(cfg.n_cores, rel=0.02)


def test_big_cores_draw_more():
    """With the calibrated big.LITTLE tables, big cores burn more J and
    the summary surfaces tput_per_watt + edp."""
    cfg = sl.with_columns(_cfg("fifo", sim_time_us=10_000.0),
                          **energy.amp_power(sl.SimConfig().big))
    s = sl.summarize(cfg, jax.tree.map(np.asarray, sl.run(cfg, 1e9)))
    e = np.asarray(s["energy_per_core_j"])
    assert e[:4].min() > e[4:].max()                   # big >> little
    assert s["energy_j"] > 0 and s["tput_per_watt"] > 0
    assert np.isfinite(s["edp"]) and s["edp"] > 0


def test_dvfs_speeds_up_and_cubes_power():
    """Doubling every core's clock raises throughput (shorter CS) and
    raises energy superlinearly (f^3 spin/active draw)."""
    slow = sl.with_columns(_cfg("fifo", sim_time_us=10_000.0),
                           **energy.amp_power(sl.SimConfig().big))
    fast = sl.with_columns(slow, dvfs=(2.0,) * 8)
    a = sl.summarize(slow, jax.tree.map(np.asarray, sl.run(slow, 1e9)))
    b = sl.summarize(fast, jax.tree.map(np.asarray, sl.run(fast, 1e9)))
    assert b["throughput_cs_per_s"] > a["throughput_cs_per_s"]
    assert b["energy_j"] > 2.0 * a["energy_j"]


def test_energy_sweeps_as_table_axis():
    """Power tables batch as table sweep axes — the whole big-vs-little
    power comparison is one executable, each cell == its single run."""
    cfg = _cfg("shfl", sim_time_us=3_000.0)
    tabs = [(0.0,) * 8, (1.0,) * 8, tuple(energy.amp_power(
        sl.SimConfig().big)["p_cs"])]
    n0 = sl.n_batch_executables()
    st, grid = sl.sweep(cfg, {"p_cs": tabs}, slo_us=200.0)
    assert sl.n_batch_executables() - n0 <= 1
    for i, tab in enumerate(grid["p_cs"]):
        single = sl.run(sl.with_columns(cfg, p_cs=tuple(tab)), 200.0)
        cell = jax.tree.map(lambda x: np.asarray(x)[i], st)
        np.testing.assert_allclose(
            sl.summarize(cfg, cell)["energy_j"],
            sl.summarize(cfg, jax.tree.map(np.asarray, single))["energy_j"],
            rtol=1e-6)


def test_dvfs_validation_rejects_nonpositive():
    with pytest.raises(ValueError, match="> 0"):
        sl.with_columns(_cfg(), dvfs=(0.0,) * 8)
    with pytest.raises(ValueError, match="NaN"):
        dataclasses.replace(_cfg(), p_cs=(-1.0,) * 8)
