"""Batched sweep-engine tests: batched-vs-sequential parity, determinism,
core padding, and chunked-scan invariance.

Tolerances: parity assertions are *exact* (event counts) or rtol=1e-9
(float summaries).  The batched path runs the same per-event HLO as the
single-run path — masked handlers with the batch dimension vmapped, a
shape-independent weighted pick for every RNG draw — so on CPU the
trajectories are bit-identical, not merely statistically close.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import simlock as sl


def _cell(st, i):
    return jax.tree.map(lambda x: np.asarray(x)[i], st)


def _close(got, want):
    assert got["events"] == want["events"]
    np.testing.assert_allclose(got["throughput_cs_per_s"],
                               want["throughput_cs_per_s"], rtol=1e-9)
    np.testing.assert_allclose(got["ep_p99_all_us"], want["ep_p99_all_us"],
                               rtol=1e-9)
    assert got["cs_per_core"] == want["cs_per_core"]


def test_sweep_slo_matches_per_config_run():
    cfg = sl.SimConfig(policy="libasl", sim_time_us=10_000.0)
    st, grid = sl.sweep(cfg, {"slo_us": [30.0, 70.0]})
    for i, slo in enumerate(grid["slo_us"]):
        _close(sl.summarize(cfg, _cell(st, i)),
               sl.summarize(cfg, sl.run(cfg, float(slo))))


def test_sweep_traced_policy_params_match_run():
    """w_big / prop_n ride as traced batch axes; cells == per-config runs."""
    tas = sl.SimConfig(policy="tas", sim_time_us=10_000.0)
    st, grid = sl.sweep(tas, {"w_big": [0.15, 8.0]})
    for i, w in enumerate(grid["w_big"]):
        _close(sl.summarize(tas, _cell(st, i)),
               sl.summarize(tas, sl.run(
                   dataclasses.replace(tas, w_big=float(w)), 1e9)))

    prop = sl.SimConfig(policy="prop", sim_time_us=10_000.0)
    st, grid = sl.sweep(prop, {"prop_n": [1, 20]})
    for i, p in enumerate(grid["prop_n"]):
        _close(sl.summarize(prop, _cell(st, i)),
               sl.summarize(prop, sl.run(
                   dataclasses.replace(prop, prop_n=int(p)), 1e9)))


def test_sweep_determinism():
    cfg = sl.SimConfig(policy="libasl", sim_time_us=8_000.0)
    a, _ = sl.sweep(cfg, {"slo_us": [50.0, 90.0], "seed": [0, 1]})
    b, _ = sl.sweep(cfg, {"slo_us": [50.0, 90.0], "seed": [0, 1]})
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # distinct seeds took distinct trajectories (events differ somewhere)
    ev = np.asarray(a.events).reshape(2, 2)
    assert (ev >= 0).all()


def test_padded_n_cores_matches_unpadded():
    """A cell running n<N cores padded to N == a dedicated n-core config."""
    for policy in ("fifo", "libasl"):
        cfg8 = sl.SimConfig(policy=policy, sim_time_us=10_000.0)
        st, _ = sl.sweep(cfg8, {"n_cores": [5]}, slo_us=60.0)
        got = sl.summarize(cfg8, _cell(st, 0), n_active=5)
        cfg5 = sl.SimConfig(
            policy=policy, n_cores=5, big=(1, 1, 1, 1, 0),
            speed_cs=(1.0,) * 4 + (3.75,), speed_nc=(1.0,) * 4 + (1.8,),
            sim_time_us=10_000.0)
        _close(got, sl.summarize(cfg5, sl.run(cfg5, 60.0)))


def test_chunked_scan_invariance():
    """chunk=1 (the seed's one-event-per-iteration loop) == chunk=128."""
    base = sl.SimConfig(policy="libasl", sim_time_us=4_000.0)
    r1 = sl.run(dataclasses.replace(base, chunk=1), 50.0, seed=3)
    r128 = sl.run(dataclasses.replace(base, chunk=128), 50.0, seed=3)
    for x, y in zip(jax.tree.leaves(r1), jax.tree.leaves(r128)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_sweep_zip_mode_and_window0_axis():
    cfg = sl.SimConfig(policy="libasl", sim_time_us=8_000.0)
    st, grid = sl.sweep(cfg, {"slo_us": [0.0, 1e5],
                              "window0_us": [10.0, 1e5]}, product=False)
    assert np.asarray(st.events).shape == (2,)
    # MAX-window cell must out-throughput the zero-SLO (FIFO-like) cell
    s0 = sl.summarize(cfg, _cell(st, 0))
    s1 = sl.summarize(cfg, _cell(st, 1))
    assert s1["throughput_cs_per_s"] > s0["throughput_cs_per_s"]


def test_resumed_run_regrows_collapsed_windows():
    """The AIMD unit floor is seeded from default_window_us, not from the
    carried windows — a resume after total window collapse (FIFO
    fallback) must regrow once the SLO becomes achievable again (zero
    would otherwise be absorbing: growth is +unit, unit ~ window)."""
    cfg = sl.SimConfig(policy="libasl", sim_time_us=15_000.0)
    collapsed = sl.run(cfg, 0.0)                  # SLO=0: windows -> ~0
    assert float(np.asarray(collapsed.window)[4:].max()) < 1.0 * sl.US
    resumed = sl.run(cfg, 200.0, 0, np.asarray(collapsed.window))
    assert float(np.asarray(resumed.window)[4:].mean()) > 1.0 * sl.US


def test_sweep_rejects_unknown_axis_and_oversize_n():
    cfg = sl.SimConfig(policy="fifo", sim_time_us=1_000.0)
    with pytest.raises(ValueError):
        sl.sweep(cfg, {"bogus": [1]})
    with pytest.raises(ValueError):
        sl.sweep(cfg, {"n_cores": [cfg.n_cores + 1]})


# ---------------------------------------------------------------------------
# Resumable sweeps (sweep(resume_dir=...) over repro.ckpt.checkpointer)
# ---------------------------------------------------------------------------

def test_sweep_resume_bit_parity_after_kill(tmp_path, monkeypatch):
    """Kill-and-resume: a sweep interrupted after its first chunk and
    re-launched into the same directory must (a) not re-execute the
    completed chunk and (b) return results bit-identical to an
    uninterrupted run."""
    cfg = sl.SimConfig(policy="libasl", sim_time_us=3_000.0)
    axes = {"slo_us": [30.0, 50.0, 70.0, 90.0, 110.0], "seed": [0, 1]}
    want, _ = sl.sweep(cfg, axes)

    d = tmp_path / "resume"
    calls = {"n": 0}
    real_exec = sl._batch_executable

    def counting_exec(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2 and not (d / "poisoned").exists():
            (d / "poisoned").touch()
            raise KeyboardInterrupt("simulated kill mid-sweep")
        return real_exec(*a, **kw)

    monkeypatch.setattr(sl, "_batch_executable", counting_exec)
    with pytest.raises(KeyboardInterrupt):
        sl.sweep(cfg, axes, resume_dir=d, resume_chunk=4)
    killed_at = calls["n"]
    st, grid = sl.sweep(cfg, axes, resume_dir=d, resume_chunk=4)
    # chunk 0 (4 cells) was restored from disk, not re-executed
    assert calls["n"] == killed_at + 2
    assert len(grid["slo_us"]) == 10
    for x, y in zip(jax.tree.leaves(want), jax.tree.leaves(st)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_sweep_resume_rejects_mismatched_grid(tmp_path):
    cfg = sl.SimConfig(policy="libasl", sim_time_us=1_000.0)
    d = tmp_path / "resume"
    sl.sweep(cfg, {"slo_us": [30.0, 50.0]}, resume_dir=d)
    with pytest.raises(ValueError, match="different sweep"):
        sl.sweep(cfg, {"slo_us": [30.0, 50.0]}, seed=1, resume_dir=d)


def test_sweep_resume_rejects_policy_kw_drift(tmp_path):
    """policy_kw values ride traced in SimParams.pol — the resume
    fingerprint digests them, so editing a knob between runs must not
    splice old chunks into the new sweep."""
    d = tmp_path / "resume"
    axes = {"slo_us": [30.0, 50.0]}
    cfg = sl.SimConfig(policy="shfl", sim_time_us=1_000.0,
                       policy_kw=(("shfl_bound", 4),))
    sl.sweep(cfg, axes, resume_dir=d)
    drift = dataclasses.replace(cfg, policy_kw=(("shfl_bound", 16),))
    with pytest.raises(ValueError, match="different sweep"):
        sl.sweep(drift, axes, resume_dir=d)
    # unchanged knobs still resume cleanly
    sl.sweep(cfg, axes, resume_dir=d)


def test_sweep_resume_rejects_column_drift(tmp_path):
    """Registered-column tables (owned or built-in) are digested too:
    a changed per-core table invalidates the directory."""
    d = tmp_path / "resume"
    axes = {"slo_us": [30.0, 50.0]}
    cfg = sl.with_columns(
        sl.SimConfig(policy="dvfs_race", sim_time_us=1_000.0),
        race_w=(1.0,) * 4, dvfs=(1.0,) * 4)
    sl.sweep(cfg, axes, resume_dir=d)
    for drift in (sl.with_columns(cfg, race_w=(2.0,) * 4),
                  sl.with_columns(cfg, dvfs=(1.5,) * 4),
                  sl.with_columns(cfg, slo_scale=(4.0,) * 4)):
        with pytest.raises(ValueError, match="different sweep"):
            sl.sweep(drift, axes, resume_dir=d)
    sl.sweep(cfg, axes, resume_dir=d)


def test_sweep_resume_incompatible_with_mesh(tmp_path):
    from repro.launch.mesh import make_sweep_mesh
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 (virtual) device")
    cfg = sl.SimConfig(policy="fifo", sim_time_us=1_000.0)
    with pytest.raises(ValueError, match="resume"):
        sl.sweep(cfg, {"seed": [0, 1]}, resume_dir=tmp_path / "r",
                 mesh=make_sweep_mesh())


# ---------------------------------------------------------------------------
# Mesh-sharded sweeps (conftest virtualizes 8 host devices)
# ---------------------------------------------------------------------------

def _mesh():
    from repro.launch.mesh import make_sweep_mesh
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 (virtual) device")
    return make_sweep_mesh()


def test_sharded_sweep_bit_identical_to_unsharded():
    """The tentpole invariant: sharding the cell dimension over the device
    mesh changes the schedule, not the numbers — every SimState leaf is
    exactly equal, including a non-divisible cell count (6 cells over 8
    devices => pad + trim)."""
    mesh = _mesh()
    cfg = sl.SimConfig(policy="libasl", sim_time_us=6_000.0)
    axes = {"slo_us": [30.0, 50.0, 70.0], "seed": [0, 1]}
    a, ga = sl.sweep(cfg, axes)
    b, gb = sl.sweep(cfg, axes, mesh=mesh)
    for k in ga:
        np.testing.assert_array_equal(ga[k], gb[k])
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_sharded_sweep_follows_row_splits():
    """Per-device work obeys the sweep sharding rules: the cell axis is
    tiled in equal contiguous row splits over the mesh's data axis."""
    from repro.dist.sharding import build_sweep_rules, row_splits
    mesh = _mesh()
    rules = build_sweep_rules(mesh)
    n_shards = rules.num_shards("cells")
    assert n_shards == len(jax.devices())
    cfg = sl.SimConfig(policy="fifo", sim_time_us=2_000.0)
    n_cells = 2 * n_shards
    st, _ = sl.sweep(cfg, {"seed": list(range(n_cells))}, mesh=mesh)
    assert st.events.sharding.spec == rules.spec(("cells",), (n_cells,))
    splits = row_splits(n_cells, n_shards)
    got = [s.data.shape[0] for s in st.events.addressable_shards]
    assert sorted(got) == sorted(splits)


def test_sharded_executable_records_collectives():
    """The batched executable's accounting record: a sharded sweep carries
    cross-device collectives (the while_loop termination reduce), an
    unsharded one carries none."""
    mesh = _mesh()
    cfg = sl.SimConfig(policy="tas", sim_time_us=2_000.0)
    axes = {"w_big": [0.5, 1.0, 2.0, 4.0] * 2}
    n0 = len(sl.sweep_log())
    sl.sweep(cfg, axes)
    sl.sweep(cfg, axes, mesh=mesh)
    unsharded, sharded = sl.sweep_log()[n0:]
    assert unsharded["devices"] == 1
    assert unsharded["collectives"]["total_count"] == 0
    assert sharded["devices"] == len(jax.devices())
    assert sharded["collectives"]["total_count"] > 0
    assert sharded["flops"] >= 0.0


def test_sweep_rules_degrade_without_data_axis():
    """A mesh without the requested data axis replicates instead of
    failing (same degradation discipline as the model rules)."""
    from repro.dist.sharding import build_sweep_rules
    mesh = _mesh()
    rules = build_sweep_rules(mesh, data_axis="model")
    assert rules.num_shards("cells") == 1
    cfg = sl.SimConfig(policy="fifo", sim_time_us=1_000.0)
    a, _ = sl.sweep(cfg, {"seed": [0, 1]})
    b, _ = sl.sweep(cfg, {"seed": [0, 1]}, mesh=mesh, data_axis="model")
    np.testing.assert_array_equal(np.asarray(a.events),
                                  np.asarray(b.events))
