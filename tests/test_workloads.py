"""Workload-subsystem tests: generator moments, counter-based
determinism, trace record->replay, open/closed-loop parity, workload
sweep axes (one executable, sharded bit-parity), multi-class clients,
and AIMDWindow vs aimd_update trajectory parity.
"""

import dataclasses

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _minihypothesis import given, settings, strategies as st

from repro.core import simlock as sl
from repro.core.aimd import AIMDWindow, aimd_update
from repro.workloads import generators as wlg
from repro.workloads import traces as wlt
from repro.workloads.clients import (ClientClass, WorkloadMix, amp_config,
                                     assign_cores)
from repro.workloads.generators import ArrivalSpec, ServiceSpec

SET = dict(max_examples=10, deadline=None)


# ---------------------------------------------------------------------------
# Generator moment sanity
# ---------------------------------------------------------------------------

@given(rate=st.floats(0.5, 50.0), seed=st.integers(0, 1000))
@settings(**SET)
def test_poisson_arrival_rate(rate, seed):
    t = wlg.arrival_times(ArrivalSpec("poisson", rate), 400.0, seed)
    assert len(t) == pytest.approx(400.0 * rate, rel=0.15)
    gaps = np.diff(t)
    assert gaps.mean() == pytest.approx(1.0 / rate, rel=0.15)
    # exponential: cv ~ 1
    assert gaps.std() / gaps.mean() == pytest.approx(1.0, abs=0.2)


@given(cv=st.floats(0.2, 2.5), seed=st.integers(0, 1000))
@settings(**SET)
def test_lognormal_service_moments(cv, seed):
    s = wlg.service_times(ServiceSpec("lognormal", mean=3.0, cv=cv),
                          20_000, seed)
    assert s.mean() == pytest.approx(3.0, rel=0.1)
    assert s.std() / s.mean() == pytest.approx(cv, rel=0.2)
    assert (s > 0).all()


@given(mix=st.floats(0.05, 0.5), scale=st.floats(2.0, 50.0),
       seed=st.integers(0, 1000))
@settings(**SET)
def test_bimodal_service_mean_preserved(mix, scale, seed):
    spec = ServiceSpec("bimodal", mean=2.0, mix=mix, mix_scale=scale)
    s = wlg.service_times(spec, 20_000, seed)
    assert s.mean() == pytest.approx(2.0, rel=0.1)
    assert len(np.unique(np.round(s, 9))) == 2     # exactly two modes


def test_mmpp_mean_rate_and_burstiness():
    spec = ArrivalSpec("mmpp", rate=20.0, burstiness=10.0, burst_len=50.0)
    t = wlg.arrival_times(spec, 2_000.0, seed=5)
    assert len(t) == pytest.approx(2_000.0 * 20.0, rel=0.2)
    # burstier than Poisson: index of dispersion of 1s bin counts >> 1
    counts = np.histogram(t, bins=int(t[-1]))[0]
    poisson_t = wlg.arrival_times(ArrivalSpec("poisson", 20.0), 2_000.0, 5)
    pcounts = np.histogram(poisson_t, bins=int(poisson_t[-1]))[0]
    assert counts.var() / counts.mean() > 2.0 * pcounts.var() / pcounts.mean()


def test_diurnal_ramp_modulates_rate():
    spec = ArrivalSpec("diurnal", rate=50.0, amp=0.9, period=100.0)
    t = wlg.arrival_times(spec, 400.0, seed=7)
    # first half-period (sin>0) must be busier than the second (sin<0)
    phase = (t % 100.0) / 100.0
    busy = np.sum(phase < 0.5)
    quiet = np.sum(phase >= 0.5)
    assert busy > 1.5 * quiet
    assert len(t) == pytest.approx(400.0 * 50.0, rel=0.2)


def test_closed_arrivals_are_deterministic_gaps():
    t = wlg.arrival_times(ArrivalSpec("closed", 10.0), 10.0, seed=0)
    np.testing.assert_allclose(np.diff(t), 0.1, rtol=1e-12)


# ---------------------------------------------------------------------------
# Counter-based determinism + trace record/replay
# ---------------------------------------------------------------------------

def test_counter_draws_independent_of_block_size():
    """Growing a draw block never perturbs its prefix (pure counters)."""
    a = wlg.uniform_block(3, wlg.STREAM_THINK, 50)
    b = wlg.uniform_block(3, wlg.STREAM_THINK, 5000)
    np.testing.assert_array_equal(a, b[:50])


def test_trace_generate_is_deterministic_and_seed_sensitive():
    spec = (ArrivalSpec("mmpp", 20.0, burstiness=5.0),
            ServiceSpec("bimodal", mean=0.1, mix=0.2))
    a = wlt.generate(*spec, 50.0, seed=1)
    b = wlt.generate(*spec, 50.0, seed=1)
    c = wlt.generate(*spec, 50.0, seed=2)
    np.testing.assert_array_equal(a.arrival_t, b.arrival_t)
    np.testing.assert_array_equal(a.service_s, b.service_s)
    assert not np.array_equal(a.arrival_t, c.arrival_t)


def test_trace_npz_roundtrip_bit_exact(tmp_path):
    mix = WorkloadMix((
        ClientClass("lc", weight=3.0, slo=0.5,
                    service=ServiceSpec("lognormal", mean=0.1, cv=1.0)),
        ClientClass("be", weight=1.0, slo=5.0,
                    service=ServiceSpec("exp", mean=0.3)),
    ))
    tr = wlt.generate(ArrivalSpec("poisson", 30.0), None, 20.0, seed=4,
                      classes=mix,
                      cols=wlt.request_columns([128, 256], [8, 16]))
    p = wlt.save(tmp_path / "wl.npz", tr)
    back = wlt.load(p)
    np.testing.assert_array_equal(tr.arrival_t, back.arrival_t)
    np.testing.assert_array_equal(tr.service_s, back.service_s)
    np.testing.assert_array_equal(tr.klass, back.klass)
    np.testing.assert_array_equal(tr.slo, back.slo)
    assert back.classes == ("lc", "be")
    for k in tr.cols:
        np.testing.assert_array_equal(tr.cols[k], back.cols[k])
    assert back.meta["seed"] == 4
    # mix ratios: ~3:1 by weight
    frac_lc = float(np.mean(back.klass == 0))
    assert frac_lc == pytest.approx(0.75, abs=0.08)


def test_dispatch_replays_trace_identically(tmp_path):
    """The dispatch sim consuming one trace twice (once from disk) is
    bit-identical — the trace IS the workload."""
    from repro.serving.dispatch import simulate_dispatch
    tr = wlt.generate(ArrivalSpec("poisson", 25.0),
                      ServiceSpec("lognormal", mean=0.1,
                                  cv=wlg.LEGACY_LOGNORMAL_CV),
                      60.0, seed=9)
    back = wlt.load(wlt.save(tmp_path / "d.npz", tr))
    m1 = simulate_dispatch("asl", slo=0.5, trace=tr)
    m2 = simulate_dispatch("asl", slo=0.5, trace=back)
    assert m1 == m2


def test_engine_replays_trace_identically(tmp_path):
    from repro.serving.engine import ServingEngine, replay_workload
    tr = wlt.generate(ArrivalSpec("poisson", 2.0), ServiceSpec(), 30.0,
                      seed=2,
                      cols=wlt.request_columns([2048, 4096], [16, 32]))
    back = wlt.load(wlt.save(tmp_path / "e.npz", tr))
    m1 = replay_workload(ServingEngine("asl", seed=1), tr,
                         slo_ttft=0.6).metrics()
    m2 = replay_workload(ServingEngine("asl", seed=1), back,
                         slo_ttft=0.6).metrics()
    assert m1 == m2


def test_sim_epoch_draws_match_host_reconstruction():
    """Device-side and host-side sims consume identical workloads: the
    simulator's final per-core (scale, svc_scale, wl_on) state equals
    the host's counter-based reconstruction at each core's epoch index."""
    cfg = sl.SimConfig(policy="fifo", wl=True, wl_process="mmpp",
                       wl_burst=6.0, wl_burst_len=12.0,
                       wl_service="lognormal", wl_cv=1.3,
                       sim_time_us=4_000.0)
    st = sl.run(cfg, 1e9, seed=11)
    ep = np.asarray(st.ep_cnt)
    think, svc = wlg.epoch_scale_tables(
        11, cfg.n_cores, int(ep.max()) + 1, process="mmpp", rate=1.0,
        cv=1.3, burstiness=6.0, burst_len=12.0, service="lognormal")
    got_scale = np.asarray(st.scale)
    got_svc = np.asarray(st.svc_scale)
    for c in range(cfg.n_cores):
        np.testing.assert_allclose(got_scale[c], think[c, ep[c]],
                                   rtol=1e-6)
        np.testing.assert_allclose(got_svc[c], svc[c, ep[c]], rtol=1e-6)


# ---------------------------------------------------------------------------
# Workload axes in the batched sweep engine (acceptance: <=1 executable
# per policy, sharded == unsharded bit-exact)
# ---------------------------------------------------------------------------

def _wl_cfg(**kw):
    base = dict(policy="libasl", wl=True, wl_process="poisson",
                wl_service="lognormal", wl_cv=1.0, sim_time_us=5_000.0)
    base.update(kw)
    return sl.SimConfig(**base)


def test_workload_sweep_single_executable_and_cell_parity():
    cfg = _wl_cfg()
    n0 = sl.n_batch_executables()
    st, grid = sl.sweep(cfg, {"arrival_rate": [0.5, 1.0, 2.0],
                              "cv": [0.5, 2.0]}, slo_us=80.0)
    assert sl.n_batch_executables() - n0 == 1
    assert np.asarray(st.events).shape == (6,)
    # cell 0 == a dedicated single run with the same traced values
    c0 = dataclasses.replace(cfg, wl_rate=0.5, wl_cv=0.5)
    want = sl.summarize(c0, sl.run(c0, 80.0))
    got = sl.summarize(cfg, jax.tree.map(lambda x: np.asarray(x)[0], st))
    assert got["events"] == want["events"]
    np.testing.assert_allclose(got["throughput_cs_per_s"],
                               want["throughput_cs_per_s"], rtol=1e-9)


def test_workload_sweep_sharded_bit_identical():
    """Acceptance: a stochastic-workload sweep is bit-identical sharded
    vs unsharded on the 8 virtual devices (counter-based draws cannot
    see the sharding)."""
    from repro.launch.mesh import make_sweep_mesh
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 (virtual) device")
    cfg = _wl_cfg(wl_process="mmpp", wl_burst=4.0)
    axes = {"arrival_rate": [0.5, 1.5, 3.0], "burstiness": [1.0, 8.0]}
    a, ga = sl.sweep(cfg, axes, slo_us=100.0)
    b, gb = sl.sweep(cfg, axes, slo_us=100.0, mesh=make_sweep_mesh())
    for k in ga:
        np.testing.assert_array_equal(ga[k], gb[k])
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_closed_loop_engine_driver_policy_independent_workload():
    """The closed-loop engine driver: deterministic per seed, and one
    client's (think, shape) sequence cannot depend on the policy under
    test (per-(client, submission) counters, not a global draw order)."""
    from repro.serving.engine import ServingEngine, closed_loop_workload

    def run(policy):
        kw = dict(default_window=0.02, max_window=10.0) \
            if policy == "asl" else {}
        eng = closed_loop_workload(
            ServingEngine(policy, scheduler_kwargs=kw),
            n_clients=1, think_s=0.2, duration_s=20.0,
            prompt_lens=[2048, 4096, 8192], new_tokens=[16, 32, 64],
            slo_ttft=0.6, seed=0)
        return [(r.prompt_len, r.max_new_tokens)
                for r in sorted(eng.done, key=lambda r: r.rid)]

    asl, fifo = run("asl"), run("fifo")
    n = min(len(asl), len(fifo))
    assert n > 20
    assert asl[:n] == fifo[:n]          # identical workload per client
    assert run("asl") == asl            # deterministic per seed


def test_open_vs_closed_loop_parity_at_matched_load():
    """At low offered load the open-loop (Poisson think) and closed-loop
    (deterministic think) systems see the same mean rates — throughput
    within 10%; padding/queueing differences only appear near
    saturation."""
    tputs = {}
    for proc in ("closed", "poisson"):
        cfg = sl.SimConfig(policy="fifo", wl=True, wl_process=proc,
                           wl_rate=0.25, sim_time_us=30_000.0)
        s = sl.summarize(cfg, sl.run(cfg, 1e9, seed=3))
        tputs[proc] = s["throughput_cs_per_s"]
    assert tputs["poisson"] == pytest.approx(tputs["closed"], rel=0.1)


def test_workload_off_bit_shares_executable_with_seed_path():
    """wl=False configs must keep compiling to the same canonical key
    regardless of wl_* numeric fields (they are canonicalized out)."""
    a = sl._canon(sl.SimConfig(policy="fifo"))
    b = sl._canon(sl.SimConfig(policy="fifo", wl_rate=7.0, wl_cv=3.0,
                               wl_process="mmpp", slo_scale=(2.0,) * 8))
    assert a == b
    c = sl._canon(sl.SimConfig(policy="fifo", wl=True))
    assert c != a


# ---------------------------------------------------------------------------
# Multi-class clients
# ---------------------------------------------------------------------------

def test_assign_cores_honors_affinity_and_weights():
    mix = WorkloadMix((
        ClientClass("lc", weight=1.0, slo=100.0, affinity="big"),
        ClientClass("be", weight=1.0, slo=1000.0, affinity="little"),
    ))
    big = (1, 1, 1, 1, 0, 0, 0, 0)
    assign = assign_cores(mix, big)
    for c, k in enumerate(assign):
        assert (k == 0) == bool(big[c])


def test_amp_config_slo_scale_rides_in_tables():
    mix = WorkloadMix((
        ClientClass("lc", weight=1.0, slo=50.0, affinity="big"),
        ClientClass("be", weight=1.0, slo=500.0, affinity="little"),
    ))
    cfg, assign = amp_config(sl.SimConfig(policy="libasl",
                                          sim_time_us=4_000.0), mix,
                             base_slo=50.0)
    assert cfg.slo_scale == (1.0,) * 4 + (10.0,) * 4
    tb = sl.build_tables(cfg)
    np.testing.assert_array_equal(np.asarray(tb.col["slo_scale"]),
                                  np.asarray(cfg.slo_scale, np.float32))
    st = sl.run(cfg, 50.0, seed=0)          # base_slo as the run SLO
    assert int(st.events) > 0


def test_multiclass_engine_keeps_per_class_windows():
    from repro.serving.engine import CostModel, ServingEngine
    from repro.workloads.clients import (metrics_by_class,
                                         multiclass_workload)
    mix = WorkloadMix((
        ClientClass("lc", weight=1.0, slo=0.3,
                    service=ServiceSpec("exp", mean=1.0)),
        ClientClass("be", weight=1.0, slo=3.0,
                    service=ServiceSpec("exp", mean=1.0)),
    ))
    eng = ServingEngine("asl", CostModel(), scheduler_kwargs=dict(
        default_window=0.02, max_window=10.0), seed=0)
    multiclass_workload(eng, mix, rate_rps=2.0, duration_s=40.0,
                        prompt_lens=[2048, 4096], new_tokens=[16, 32],
                        seed=1)
    per = metrics_by_class(eng, mix)
    assert per["lc"]["n"] > 0 and per["be"]["n"] > 0
    # one AIMD window per class, and the tight class converged tighter
    assert set(eng.sched._windows) == {0, 1}
    assert eng.sched.window(0) <= eng.sched.window(1)


# ---------------------------------------------------------------------------
# AIMDWindow vs aimd_update trajectory parity
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 5000), n=st.integers(1, 60),
       slo=st.floats(10.0, 1000.0))
@settings(**SET)
def test_aimd_host_vs_jnp_trajectory_parity(seed, n, slo):
    """Sequence-level parity: iterating the host AIMDWindow and the
    functional aimd_update over one latency stream stays in lockstep
    (the single-step test cannot catch drift through the carried unit)."""
    lat = wlg.uniform_block(seed, 0x1234, n) * 2.0 * slo
    host = AIMDWindow(window=3 * slo, unit=3 * slo * 0.01, pct=99.0,
                      max_window=1e6)
    w = np.float32(3 * slo)
    u = np.float32(3 * slo * 0.01)
    for x in lat:
        host.update(float(x), slo)
        w, u = aimd_update(w, u, np.float32(x), np.float32(slo),
                           pct=99.0, max_window=1e6)
        np.testing.assert_allclose(float(w), host.window, rtol=1e-5)
        np.testing.assert_allclose(float(u), host.unit, rtol=1e-5)


# ---------------------------------------------------------------------------
# Per-core service distributions (SimTables.wl_service — multi-class
# tenants with different service shapes side by side)
# ---------------------------------------------------------------------------

def test_per_core_service_moments():
    """Big cores run det, little cores bimodal: the reconstructed (and
    simulator-consumed) per-core service draws must carry each core's
    own distribution — det exactly 1, bimodal mean-1 with the analytic
    second moment."""
    per_core = ("det",) * 4 + ("bimodal",) * 4
    mix, scale = 0.2, 10.0
    n_ep = 4000
    _, svc = wlg.epoch_scale_tables(
        5, 8, n_ep, process="poisson", rate=1.0, mix=mix,
        mix_scale=scale, service=list(per_core))
    assert np.array_equal(svc[:4], np.ones((4, n_ep)))
    little = svc[4:].ravel()
    short = 1.0 / ((1.0 - mix) + mix * scale)
    ex2 = (1.0 - mix) * short ** 2 + mix * (short * scale) ** 2
    assert np.mean(little) == pytest.approx(1.0, rel=0.05)
    assert np.mean(little ** 2) == pytest.approx(ex2, rel=0.10)
    # two-point support (draws are f32; compare at f32 precision)
    np.testing.assert_allclose(np.unique(little), [short, short * scale],
                               rtol=1e-6)


def test_per_core_service_table_rides_in_sim():
    """The wl_service column drives the simulator: a det/bimodal split
    run's final svc_scale matches the per-core host reconstruction, and
    the default (inherit) table is bit-identical to the scalar path."""
    cfg = sl.SimConfig(policy="fifo", wl=True, wl_service="exp",
                       wl_mix=0.3, sim_time_us=4_000.0,
                       wl_service_per_core=(None,) * 4 + ("bimodal",) * 4)
    st = sl.run(cfg, 1e9, seed=9)
    ep = np.asarray(st.ep_cnt)
    _, svc = wlg.epoch_scale_tables(
        9, cfg.n_cores, int(ep.max()) + 1, process="poisson", rate=1.0,
        mix=0.3, service=["exp"] * 4 + ["bimodal"] * 4)
    got = np.asarray(st.svc_scale)
    for c in range(cfg.n_cores):
        np.testing.assert_allclose(got[c], svc[c, ep[c]], rtol=1e-6)
    # inherit-only table == the scalar wl_service path, exactly
    plain = sl.run(dataclasses.replace(cfg, wl_service_per_core=()),
                   1e9, seed=9)
    explicit = sl.run(dataclasses.replace(
        cfg, wl_service_per_core=("exp",) * 8), 1e9, seed=9)
    for x, y in zip(jax.tree.leaves(plain), jax.tree.leaves(explicit)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_per_core_service_is_sweepable_table_axis():
    """wl_service_per_core sweeps as a table axis — one executable for
    the mixed-shape grid, cells == dedicated runs."""
    base = sl.SimConfig(policy="fifo", wl=True, wl_service="det",
                        sim_time_us=3_000.0)
    tables = [(None,) * 8, ("bimodal",) * 4 + (None,) * 4]
    n0 = sl.n_batch_executables()
    st, grid = sl.sweep(base, {"wl_service_per_core": tables}, slo_us=1e9)
    assert sl.n_batch_executables() - n0 <= 1
    for i, tab in enumerate(tables):
        want = sl.run(dataclasses.replace(base, wl_service_per_core=tab),
                      1e9)
        got = jax.tree.map(lambda x, i=i: np.asarray(x)[i], st)
        assert int(got.events) == int(want.events)
        np.testing.assert_allclose(np.asarray(got.svc_scale),
                                   np.asarray(want.svc_scale), rtol=1e-9)


def test_amp_config_installs_per_core_service():
    mix = WorkloadMix((
        ClientClass("lc", weight=1.0, slo=50.0, affinity="big"),
        ClientClass("be", weight=1.0, slo=500.0, affinity="little",
                    service=ServiceSpec("bimodal", mix=0.3)),
    ))
    cfg, assign = amp_config(
        sl.SimConfig(policy="libasl", wl=True, sim_time_us=2_000.0),
        mix, base_slo=50.0)
    assert cfg.wl_service_per_core == (None,) * 4 + ("bimodal",) * 4
    tb = sl.build_tables(cfg)
    np.testing.assert_array_equal(
        np.asarray(tb.col["wl_service"]),
        [-1] * 4 + [wlg.SERVICES["bimodal"]] * 4)
