"""Per-arch smoke tests: reduced same-family configs, one forward/train step
on CPU, shape + finiteness asserts (full configs are dry-run-only)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.step import make_train_step


def _batch(cfg, b=2, s=32, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    labels = jax.random.randint(k2, (b, s), 0, cfg.vocab)
    if cfg.frontend == "audio_stub":
        return {"frames": jax.random.normal(k1, (b, s, cfg.d_model)),
                "labels": labels}
    if cfg.frontend == "vision_stub":
        p = cfg.n_patches
        return {"tokens": jax.random.randint(k1, (b, s - p), 0, cfg.vocab),
                "patch_embeds": jax.random.normal(k3, (b, p, cfg.d_model)),
                "labels": labels}
    return {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab),
            "labels": labels}


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_forward(arch):
    cfg = registry.get_tiny(arch)
    params = lm.init_params(cfg, 0)
    batch = _batch(cfg)
    logits = lm.forward(params, cfg, batch)
    b, s = batch["labels"].shape
    assert logits.shape == (b, s, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_train_step(arch):
    cfg = registry.get_tiny(arch)
    params = lm.init_params(cfg, 0)
    opt = AdamW(state_dtype=cfg.opt_state_dtype)
    step_fn = jax.jit(make_train_step(cfg, opt, cosine_schedule(1e-3, 5, 50)))
    opt_state = opt.init(params)
    batch = _batch(cfg)
    p1, o1, s1, metrics = step_fn(params, opt_state, jnp.int32(0), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) -
                                      b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert delta > 0
    # loss decreases over a few steps on a fixed batch
    p, o, st = p1, o1, s1
    first = float(metrics["loss"])
    for _ in range(5):
        p, o, st, metrics = step_fn(p, o, st, batch)
    assert float(metrics["loss"]) < first, arch


@pytest.mark.parametrize("arch", [a for a in registry.ARCHS
                                  if registry.get_tiny(a).has_decode])
def test_smoke_decode_matches_forward(arch):
    cfg = registry.get_tiny(arch)
    params = lm.init_params(cfg, 0)
    b, s, pre = 2, 24, 16
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    if cfg.frontend == "vision_stub":
        p_ = cfg.n_patches
        patch = jax.random.normal(key, (b, p_, cfg.d_model))
        full = lm.forward(params, cfg, {"tokens": toks[:, :s - p_],
                                        "patch_embeds": patch})
        cache = lm.init_cache(cfg, b, 64)
        logits, cache = lm.prefill(
            params, cfg, {"tokens": toks[:, :pre - p_],
                          "patch_embeds": patch}, cache)
        lengths = jnp.full((b,), pre, jnp.int32)
        text = toks[:, :s - p_]
        errs = [np.max(np.abs(np.asarray(logits[:, 0] - full[:, pre - 1])))]
        for t in range(pre - p_, s - p_):
            logits, cache, lengths = lm.decode_step(
                params, cfg, text[:, t:t + 1], lengths, cache)
            errs.append(np.max(np.abs(np.asarray(
                logits[:, 0] - full[:, t + p_]))))
    else:
        kw = dict(capacity_factor=8.0) if cfg.n_experts else {}
        if kw:
            import dataclasses
            cfg = dataclasses.replace(cfg, **kw)  # no token dropping
        full = lm.forward(params, cfg, {"tokens": toks})
        cache = lm.init_cache(cfg, b, 64)
        logits, cache = lm.prefill(params, cfg, {"tokens": toks[:, :pre]},
                                   cache)
        lengths = jnp.full((b,), pre, jnp.int32)
        errs = [np.max(np.abs(np.asarray(logits[:, 0] - full[:, pre - 1])))]
        for t in range(pre, s):
            logits, cache, lengths = lm.decode_step(
                params, cfg, toks[:, t:t + 1], lengths, cache)
            errs.append(np.max(np.abs(np.asarray(logits[:, 0] - full[:, t]))))
    assert max(errs) < 3e-4, (arch, errs)


def test_full_configs_construct():
    """The exact published configs build schemas & abstract params."""
    for arch in registry.ARCHS:
        cfg, meta = registry.get(arch)
        ap = lm.abstract_params(cfg)
        n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(ap))
        assert n_params > 0
        # sanity: parameter counts are in the right ballpark
        expected = {
            "llama3-405b": (3.6e11, 4.6e11),
            "grok-1-314b": (2.6e11, 3.6e11),
            "qwen1.5-110b": (0.9e11, 1.3e11),
            "phi3.5-moe-42b": (3.4e10, 4.8e10),
            "gemma-7b": (7e9, 1.0e10),
            "yi-6b": (5e9, 7e9),
            "llava-next-mistral-7b": (6.4e9, 8e9),
            "recurrentgemma-2b": (2e9, 3.4e9),
            "xlstm-125m": (1.0e8, 1.8e8),
            "hubert-xlarge": (0.8e9, 1.3e9),
        }[cfg.name]
        assert expected[0] < n_params < expected[1], (cfg.name, n_params)
