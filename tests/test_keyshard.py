"""Key-sharded datastore axis tests (docs/workloads.md §Key-sharded
traffic, docs/simulator.md §Multi-lock axis):

* the Zipf key stream — pmf moments, prefix invariance, host/device
  agreement (the engine's ``cur_lock`` matches the host reconstruction);
* the multi-lock engine — pre-refactor bit-parity (golden digests from
  ``tests/data/keyshard_golden.json``, captured at the old commit by
  ``tests/golden_digests.py``), keyed single-lock parity, lock padding
  parity, and the one-executable sweep discipline over the three key
  axes;
* config validation + the resume-fingerprint drift rejection for the
  new traced key params.
"""

import dataclasses
import json

import jax
import numpy as np
import pytest

import golden_digests as gd
from repro.core import simlock as sl
from repro.workloads import keys as wlk

GOLDEN = json.loads(gd.GOLDEN.read_text())


def _keyed(policy="fifo", **kw):
    base = dict(policy=policy, sim_time_us=2_000.0, n_locks=4,
                n_keys=256, zipf_theta=0.99)
    base.update(kw)
    return sl.SimConfig(**base)


# ---------------------------------------------------------------------------
# Zipf key stream
# ---------------------------------------------------------------------------

def test_zipf_moments():
    """Empirical key frequencies track the exact pmf: ranks 0/1 are
    exact in the Gray/YCSB inverse-CDF (tight tolerance), the tail is
    the power-law approximation (total-variation bound)."""
    n_keys, theta = 64, 0.99
    tab = wlk.key_table(0, 64, 512, n_keys, theta)
    emp = np.bincount(tab.ravel(), minlength=n_keys) / tab.size
    pmf = wlk.zipf_pmf(n_keys, theta)
    assert abs(emp[0] - pmf[0]) < 0.10 * pmf[0]
    assert abs(emp[1] - pmf[1]) < 0.15 * pmf[1]
    assert 0.5 * np.sum(np.abs(emp - pmf)) < 0.03     # total variation
    # rank-ordering: hot keys really are hotter
    assert emp[0] > emp[4] > emp[31]


def test_zipf_uniform_at_theta_zero():
    n_keys = 32
    tab = wlk.key_table(1, 64, 256, n_keys, 0.0)
    emp = np.bincount(tab.ravel(), minlength=n_keys) / tab.size
    assert 0.5 * np.sum(np.abs(emp - 1.0 / n_keys)) < 0.03


def test_key_table_prefix_invariance():
    """Counter-based draws: growing the table in either dimension never
    perturbs existing entries."""
    small = wlk.key_table(7, 8, 64, 128, 0.9)
    big = wlk.key_table(7, 16, 256, 128, 0.9)
    np.testing.assert_array_equal(small, big[:8, :64])


def test_zipf_consts_validation():
    with pytest.raises(ValueError, match="n_keys"):
        wlk.zipf_consts(0, 0.9)
    with pytest.raises(ValueError, match="theta"):
        wlk.zipf_consts(8, float("nan"))
    with pytest.raises(ValueError, match="theta"):
        wlk.zipf_consts(8, -0.5)
    # the pole is nudged, not rejected — and the nudged theta is
    # returned so host and device agree
    th, _, _, _ = wlk.zipf_consts(8, 1.0)
    assert th != 1.0 and abs(th - 1.0) < 1e-3


def test_engine_lock_matches_host_reconstruction():
    """Closed loop: after a run, every core's current lock is the host
    ``lock_table`` entry at its completed-epoch index (epoch ``ep_cnt``
    is the in-progress one — drawn at the previous release)."""
    cfg = _keyed()
    st = sl.run(cfg, 80.0, seed=3)
    tab = wlk.lock_table(3, cfg.n_cores, int(np.max(st.ep_cnt)) + 1,
                         cfg.n_keys, cfg.zipf_theta, cfg.n_locks)
    cur = np.asarray(st.cur_lock)
    ep = np.asarray(st.ep_cnt)
    for c in range(cfg.n_cores):
        assert cur[c] == tab[c, ep[c]]


def test_crew_rw_stream_matches_host():
    cfg = _keyed("ks_crew")
    st = sl.run(cfg, 80.0, seed=3)
    for c in range(cfg.n_cores):
        want = float(wlk.epoch_rw_u(3, c, int(st.ep_cnt[c])))
        assert float(st.cur_rw[c]) == want


# ---------------------------------------------------------------------------
# Engine bit-parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", sorted(GOLDEN))
def test_pre_refactor_digest_parity(policy):
    """The keyshard refactor's core guarantee: with the key gate off
    (every pre-existing config), single runs, sweeps and summaries are
    sha256-identical to the pre-refactor engine — for every policy and
    every record kind the golden file names (new state fields the
    refactor added are deliberately NOT in the file)."""
    cur = gd.capture_policy(policy)
    for kind, dig in GOLDEN[policy].items():
        if isinstance(dig, dict):
            for field, h in dig.items():
                assert cur[kind].get(field) == h, (policy, kind, field)
        else:
            assert cur[kind] == dig, (policy, kind)


#: Gate-on/gate-off comparable policies: no read/write stream (ks_crew
#: draws cur_rw when keyed, so its decisions legitimately differ).
_PARITY_FIELDS = ("t", "events", "phase", "t_ready", "seg", "ep_cnt",
                  "cs_cnt", "ep_lat", "cs_lat", "holder", "window")


@pytest.mark.parametrize("policy", ["fifo", "libasl", "ks_erew"])
def test_single_lock_keyed_matches_gate_off(policy):
    """n_locks=1 with the key gate ON is bit-identical to the gate-off
    engine on every pre-existing field: all keys bucket to lock 0, so
    the Zipf draws must not perturb the trajectory."""
    off = sl.run(sl.SimConfig(policy=policy, sim_time_us=2_000.0),
                 80.0, seed=3)
    on = sl.run(sl.SimConfig(policy=policy, sim_time_us=2_000.0,
                             n_locks=1, n_keys=64, zipf_theta=1.2),
                80.0, seed=3)
    for f in _PARITY_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(off, f)), np.asarray(getattr(on, f)), f)


def test_lock_padding_parity():
    """A swept n_locks cell runs padded to the template's cfg.n_locks —
    results must be bit-identical to an unpadded run at that count (the
    lock axis is a padded, mask-active dimension like cores)."""
    cfg = _keyed(n_locks=8)
    st_sw, _ = sl.sweep(cfg, {"n_locks": [2, 8]}, slo_us=80.0, seed=3)
    for i, nl in enumerate((2, 8)):
        single = sl.run(_keyed(n_locks=nl), 80.0, seed=3)
        cell = jax.tree.map(lambda x, i=i: x[i], st_sw)
        for f in _PARITY_FIELDS:
            if f == "holder":
                continue                      # padded shape differs
            np.testing.assert_array_equal(
                np.asarray(getattr(single, f)),
                np.asarray(getattr(cell, f)), (nl, f))
        np.testing.assert_array_equal(
            np.asarray(single.holder),
            np.asarray(cell.holder)[:nl], nl)
        np.testing.assert_array_equal(
            np.asarray(single.cur_lock), np.asarray(cell.cur_lock), nl)


def test_keyed_sweep_cell_matches_single():
    """Zipped cells over (zipf_theta, n_locks) reproduce the matching
    single runs exactly — the sweep engine's per-cell Zipf constants
    agree with build_params."""
    cfg = _keyed(n_locks=4)
    st_sw, _ = sl.sweep(cfg, {"zipf_theta": [0.5, 1.2],
                              "n_locks": [4, 2]},
                        product=False, slo_us=80.0, seed=3)
    for i, (th, nl) in enumerate(((0.5, 4), (1.2, 2))):
        single = sl.run(
            dataclasses.replace(cfg, zipf_theta=th, n_locks=nl),
            80.0, seed=3)
        cell = jax.tree.map(lambda x, i=i: x[i], st_sw)
        for f in ("t", "events", "ep_cnt", "cs_cnt", "cur_lock"):
            np.testing.assert_array_equal(
                np.asarray(getattr(single, f)),
                np.asarray(getattr(cell, f)), (th, nl, f))


def test_key_axes_share_one_executable():
    """The whole keyshard figure shape: thetas and lock counts zipped in
    one sweep call -> at most one new batched executable."""
    cfg = _keyed(n_locks=8, n_keys=512)
    axes = {"zipf_theta": [0.0, 0.9, 1.2, 0.99, 0.99],
            "n_locks": [8, 8, 8, 2, 4]}
    n0 = sl.n_batch_executables()
    st, grid = sl.sweep(cfg, axes, product=False, slo_us=80.0, seed=3)
    assert sl.n_batch_executables() - n0 <= 1
    assert np.shape(st.t)[0] == 5
    # more skew or fewer locks -> no more throughput
    eps = np.asarray(st.ep_cnt).sum(axis=1)
    assert eps[2] <= eps[0]
    assert eps[3] <= eps[0]


# ---------------------------------------------------------------------------
# Validation + sweep plumbing
# ---------------------------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError, match="n_keys"):
        sl.SimConfig(policy="fifo", n_keys=-1)
    with pytest.raises(ValueError, match="zipf_theta"):
        sl.SimConfig(policy="fifo", n_keys=8, zipf_theta=float("nan"))
    with pytest.raises(ValueError, match="zipf_theta"):
        sl.SimConfig(policy="fifo", n_keys=8, zipf_theta=-1.0)
    with pytest.raises(ValueError, match="at least one key"):
        sl.SimConfig(policy="fifo", n_locks=8, n_keys=4)
    with pytest.raises(ValueError, match="n_locks"):
        sl.SimConfig(policy="fifo", n_locks=0)


def test_sweep_rejects_key_axes_without_gate():
    cfg = sl.SimConfig(policy="fifo", sim_time_us=1_000.0)
    with pytest.raises(ValueError, match="key-shard gate"):
        sl.sweep(cfg, {"zipf_theta": [0.5, 0.9]})
    with pytest.raises(ValueError, match="key-shard gate"):
        sl.sweep(cfg, {"n_locks": [1]})


def test_sweep_n_keys_axis_flips_gate():
    cfg = sl.SimConfig(policy="fifo", sim_time_us=1_000.0, n_locks=2)
    st, grid = sl.sweep(cfg, {"n_keys": [64, 256]}, slo_us=80.0, seed=3)
    assert np.shape(st.t)[0] == 2
    assert np.any(np.asarray(st.cur_lock) > 0)   # keys actually drawn
    with pytest.raises(ValueError, match=">= 1"):
        sl.sweep(cfg, {"n_keys": [0, 64]})


def test_sweep_rejects_bad_lock_cells():
    cfg = _keyed(n_locks=4)
    with pytest.raises(ValueError, match="n_locks axis"):
        sl.sweep(cfg, {"n_locks": [2, 8]})       # exceeds padded size
    with pytest.raises(ValueError, match="n_locks axis"):
        sl.sweep(cfg, {"n_locks": [0]})
    with pytest.raises(ValueError, match="at least one key"):
        sl.sweep(cfg, {"n_keys": [2], "n_locks": [4]}, product=False)


def test_sweep_resume_rejects_key_drift(tmp_path):
    """The resume fingerprint digests the traced key params (ks_*), so
    editing the Zipf exponent or key count between runs must not splice
    old chunks into the new sweep."""
    d = tmp_path / "resume"
    axes = {"slo_us": [30.0, 50.0]}
    cfg = _keyed(sim_time_us=1_000.0)
    sl.sweep(cfg, axes, resume_dir=d)
    for drift in (dataclasses.replace(cfg, zipf_theta=1.2),
                  dataclasses.replace(cfg, n_keys=64)):
        with pytest.raises(ValueError, match="different sweep"):
            sl.sweep(drift, axes, resume_dir=d)
    # unchanged key params still resume cleanly
    sl.sweep(cfg, axes, resume_dir=d)
