"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_scan import mlstm_scan
from repro.kernels.rglru_scan import rglru_scan

TOL = {jnp.float32: 3e-5, jnp.bfloat16: 2e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kh,s,t,dh,causal,window",
    [
        (2, 4, 4, 128, 128, 64, True, 0),      # MHA causal
        (1, 8, 2, 256, 256, 64, True, 0),      # GQA 4:1
        (2, 4, 1, 128, 128, 128, True, 0),     # MQA
        (1, 4, 2, 128, 256, 64, False, 0),     # cross/bidir, longer K
        (1, 4, 2, 256, 256, 64, True, 64),     # local window
        (1, 2, 2, 512, 512, 32, True, 128),    # long + window
    ])
def test_flash_attention_sweep(b, h, kh, s, t, dh, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (b, h, s, dh), dtype)
    k = _rand(ks[1], (b, kh, t, dh), dtype)
    v = _rand(ks[2], (b, kh, t, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kh,t,dh", [
    (2, 8, 2, 256, 64),
    (3, 4, 4, 512, 128),
    (1, 16, 2, 1024, 64),
])
def test_decode_attention_sweep(b, h, kh, t, dh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (b, h, dh), dtype)
    kc = _rand(ks[1], (b, kh, t, dh), dtype)
    vc = _rand(ks[2], (b, kh, t, dh), dtype)
    lengths = jnp.asarray([(t // 3 + i * 17) % t + 1 for i in range(b)],
                          jnp.int32)
    out = decode_attention(q, kc, vc, lengths, block_k=128, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,r,h0", [
    (2, 128, 64, False),
    (1, 512, 256, True),
    (4, 64, 128, True),
])
def test_rglru_scan_sweep(b, s, r, h0, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a = jax.nn.sigmoid(_rand(ks[0], (b, s, r), jnp.float32)).astype(dtype)
    x = _rand(ks[1], (b, s, r), dtype)
    h = _rand(ks[2], (b, r), jnp.float32) if h0 else None
    out = rglru_scan(a, x, h, block_s=32, block_c=32, interpret=True)
    want = ref.rglru_scan_ref(a, x, h)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=5 * TOL[dtype], rtol=5 * TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,s,dh,with_carry", [
    (2, 2, 64, 32, False),
    (1, 4, 128, 64, True),
])
def test_mlstm_scan_sweep(b, h, s, dh, with_carry, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 8)
    q = _rand(ks[0], (b, h, s, dh), dtype)
    k = (_rand(ks[1], (b, h, s, dh), jnp.float32)
         / np.sqrt(dh)).astype(dtype)
    v = _rand(ks[2], (b, h, s, dh), dtype)
    ig = _rand(ks[3], (b, h, s), jnp.float32)
    fg = _rand(ks[4], (b, h, s), jnp.float32) + 2.0
    carry = None
    if with_carry:
        carry = (jnp.abs(_rand(ks[5], (b, h, dh, dh), jnp.float32)) * 0.1,
                 jnp.abs(_rand(ks[6], (b, h, dh), jnp.float32)) * 0.1,
                 jnp.zeros((b, h), jnp.float32))
    out, (C, n, m) = mlstm_scan(q, k, v, ig, fg, carry, block_s=32,
                                interpret=True)
    want, (Cw, nw, mw) = ref.mlstm_scan_ref(q, k, v, ig, fg, carry)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=10 * TOL[dtype], rtol=10 * TOL[dtype])
    np.testing.assert_allclose(np.asarray(C), np.asarray(Cw), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mw), atol=1e-5,
                               rtol=1e-5)


def test_model_attention_matches_kernel():
    """The model's XLA attention path == kernel semantics (same oracle)."""
    from repro.models.layers import attention
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    b, h, kh, s, dh = 2, 8, 2, 256, 64
    q = _rand(ks[0], (b, s, h, dh), jnp.float32)
    k = _rand(ks[1], (b, s, kh, dh), jnp.float32)
    v = _rand(ks[2], (b, s, kh, dh), jnp.float32)
    model_out = attention(q, k, v, causal=True, q_block=64,
                          dtype=jnp.float32)
    kern = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), causal=True,
                           block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(model_out),
                               np.asarray(kern.transpose(0, 2, 1, 3)),
                               atol=3e-5, rtol=3e-5)
