"""Examples smoke tests: run each example's ``main`` with tiny
parameters so the examples can't silently rot (they are the documented
entry points but were never executed by CI before this)."""

import importlib.util
import sys
from pathlib import Path

EXAMPLES = Path(__file__).resolve().parents[1] / "examples"


def _load(name):
    spec = importlib.util.spec_from_file_location(
        f"examples_{name}", EXAMPLES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod
    spec.loader.exec_module(mod)
    return mod


def test_quickstart_smoke(capsys):
    qs = _load("quickstart")
    # Tiny run: loss movement over 6 steps is noise, so only the
    # train -> preempt -> restore -> finish contract is asserted.
    out = qs.main(total_steps=6, preempt_at=3, ckpt_every=3,
                  global_batch=2, seq_len=16, check_loss=False)
    assert out["step"] == 6
    assert "quickstart OK" in capsys.readouterr().out


def test_lock_microbench_smoke(capsys):
    from repro.core.policies import REGISTRY
    mb = _load("lock_microbench")
    mb.main(ns=(1, 4), slos=(50.0, 150.0), sim_time_us=1_500.0,
            fracs=(0.5, 2.0))
    out = capsys.readouterr().out
    assert "Figure 1" in out and "Figure 8b" in out
    assert "Load-latency" in out and "Open-loop" in out
    assert "Key-sharded matrix" in out
    # every registered policy appears in the matrix section
    matrix = out.split("== Figure 1")[0]
    for name in REGISTRY:
        assert f"\n{name:>8} " in matrix, name
