"""End-to-end behaviour tests for the paper's system.

The paper's contract, exercised through the full stack: SLO-guided bounded
reordering keeps tail latency at the SLO while taking whatever throughput
the SLO allows — at the lock (simulator), the serving engine, and the
heterogeneous fleet; plus the train -> checkpoint -> serve lifecycle.
"""

import json
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm
from repro.serving.dispatch import simulate_dispatch, spill_index
from repro.serving.engine import CostModel, ServingEngine, poisson_workload
from repro.train.trainer import Trainer, TrainerConfig

ART = Path(__file__).resolve().parents[1] / "artifacts"


def test_serving_engine_policies_end_to_end():
    """greedy starves prefill (TAS analogue); ASL admits bounded work."""
    cost = CostModel(decode_step_s=2e-3, prefill_chunk_s=18e-3,
                     prefill_chunk=2048, max_batch=64)
    out = {}
    for sched in ("fifo", "greedy", "asl"):
        kw = {"default_window": 0.02, "max_window": 10.0} \
            if sched == "asl" else {}
        eng = ServingEngine(sched, cost, scheduler_kwargs=kw, seed=1)
        poisson_workload(eng, rate_rps=2.5, duration_s=90.0,
                         prompt_lens=[2048, 8192], new_tokens=[64, 256],
                         slo_ttft=0.6, seed=2)
        out[sched] = eng.metrics()
    # greedy: prefill starvation => far fewer completions / huge TTFT
    assert out["greedy"]["ttft_p99"] > 3 * out["asl"]["ttft_p99"]
    # ASL completes what FIFO completes (bounded reordering loses nothing)
    assert out["asl"]["n"] >= 0.9 * out["fifo"]["n"]
    # and keeps the TTFT tail in the same class as FIFO (vs greedy collapse)
    assert out["asl"]["ttft_p99"] < 2.0 * out["fifo"]["ttft_p99"]


def test_dispatch_three_regimes():
    lo = {p: simulate_dispatch(p, rate_rps=15.0, service_s=0.1, slo=0.5,
                               duration_s=120.0, seed=3)
          for p in ("fair", "fast-only", "asl")}
    hi = {p: simulate_dispatch(p, rate_rps=45.0, service_s=0.1, slo=0.5,
                               duration_s=120.0, seed=3)
          for p in ("fair", "fast-only", "asl")}
    # low load: fair puts work on slow replicas => inflated tail
    assert lo["fair"]["p99"] > 1.5 * lo["asl"]["p99"]
    # high load: fast-only saturates; ASL absorbs the spill
    assert hi["asl"]["throughput_rps"] > 1.1 * hi["fast-only"]["throughput_rps"]
    # ASL uses slow replicas only under pressure
    assert lo["asl"]["served_slow"] < 0.05 * lo["asl"]["n"]
    assert hi["asl"]["served_slow"] > 0.1 * hi["asl"]["n"]


def test_dispatch_spill_picks_earliest_deadline_not_fifo_head():
    """Paper §3.2: the standby handed to the slow pool is the expired
    request with the earliest *deadline*, not the FIFO head.  Constructed
    two-request race: the head arrived first but (its window was wider)
    expires later than the second request."""
    queue = [
        (0.0, 0.1, 5.0),   # FIFO head: arrival 0.0, deadline 5.0
        (0.1, 0.1, 2.0),   # later arrival, earlier deadline
    ]
    assert spill_index(queue, clock=6.0) == 1   # both expired: deadline order
    assert spill_index(queue, clock=3.0) == 1   # only the second expired
    assert spill_index(queue, clock=5.5) == 1   # still deadline order
    assert spill_index(queue, clock=1.0) is None  # nobody expired: no spill
    # identical deadlines: stable tie-break on queue position (FIFO)
    assert spill_index([(0.0, 0.1, 2.0), (0.1, 0.1, 2.0)], clock=3.0) == 0


def test_dispatch_throughput_counts_all_completions():
    """Regression: throughput_rps was computed from the warmup-truncated
    latency sample (~5% systematically low); it must count every
    completion (served_fast + served_slow)."""
    m = simulate_dispatch("fair", rate_rps=20.0, duration_s=60.0, seed=7)
    assert m["completed"] == m["served_fast"] + m["served_slow"]
    assert m["completed"] > m["n"]          # the trim is real
    assert m["throughput_rps"] * 60.0 == pytest.approx(m["completed"],
                                                       rel=0.25)
    # the latency sample remains the trimmed one
    assert m["n"] == m["completed"] - int(0.05 * m["completed"])


def test_train_checkpoint_serve_lifecycle(tmp_path):
    cfg = registry.get_tiny("llama3_405b")
    t = Trainer(cfg, TrainerConfig(total_steps=10, ckpt_every=5,
                                   ckpt_dir=str(tmp_path), global_batch=4,
                                   seq_len=32, lr=1e-3))
    out = t.run()
    assert out["step"] == 10
    # restore into a fresh process-equivalent and serve
    t2 = Trainer(cfg, TrainerConfig(total_steps=10, ckpt_every=5,
                                    ckpt_dir=str(tmp_path), global_batch=4,
                                    seq_len=32))
    params, _, step = t2.init_or_restore()
    assert step == 10
    cache = lm.init_cache(cfg, 2, 64)
    prompt = jnp.ones((2, 16), jnp.int32)
    logits, cache = lm.prefill(params, cfg, {"tokens": prompt}, cache)
    lengths = jnp.full((2,), 16, jnp.int32)
    toks = []
    for _ in range(8):
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        toks.append(np.asarray(nxt))
        logits, cache, lengths = lm.decode_step(
            params, cfg, nxt[:, None], lengths, cache)
        assert np.isfinite(np.asarray(logits)).all()
    assert len(toks) == 8


@pytest.mark.skipif(not (ART / "dryrun").exists(),
                    reason="dry-run artifacts not generated")
def test_dryrun_artifacts_all_ok():
    """Every recorded production (arch x shape x pod-mesh) cell compiled
    (or was a documented skip) — the multi-pod runnability contract.
    Sub-production ``mesh*`` cells (--mesh/--tiny runs) are exempt."""
    cells = [json.loads(f.read_text())
             for f in (ART / "dryrun").glob("*__pod[12].json")]
    if not cells:
        pytest.skip("no production dry-run cells recorded")
    assert len(cells) >= 80
    bad = [c["cell"] for c in cells if not c.get("ok")]
    assert not bad, bad
    pods = {c["mesh"] for c in cells if not c.get("skipped")}
    assert pods == {"16x16", "2x16x16"}


@pytest.mark.skipif(not (ART / "roofline").exists(),
                    reason="roofline artifacts not generated")
def test_roofline_decode_cells_memory_bound():
    """After §Perf, decode serving steps sit at the memory roofline."""
    for f in (ART / "roofline").glob("*decode_32k.json"):
        d = json.loads(f.read_text())
        if d.get("skipped") or not d.get("ok"):
            continue
        if d["arch"] in ("recurrentgemma-2b", "xlstm-125m"):
            continue  # sub-ms states: collective floor dominates trivially
        assert d["dominant"] == "memory", (d["cell"], d["dominant"])
