"""Property-based tests on the system's invariants.

Runs under `hypothesis` when installed (requirements-test.txt); otherwise
falls back to the vendored deterministic mini-implementation in
``tests/_minihypothesis.py`` so the suite never silently skips.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # vendored fallback: same API subset, no shrinking
    from _minihypothesis import given, settings, strategies as st

from repro.core.aimd import AIMDWindow, aimd_update
from repro.core.asl_schedule import ASLScheduler
from repro.models.layers import attention
from repro.optim.compression import dequantize_int8, quantize_int8

SET = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# AIMD (Algorithm 2) invariants
# ---------------------------------------------------------------------------

@given(w0=st.floats(1.0, 1e6), lat=st.floats(0.0, 1e9),
       slo=st.floats(1.0, 1e6), pct=st.floats(50.0, 99.9))
@settings(**SET)
def test_aimd_window_bounds(w0, lat, slo, pct):
    w = AIMDWindow(window=w0, unit=w0 * (100 - pct) / 100, pct=pct,
                   max_window=1e7)
    w.update(lat, slo)
    assert 0.0 <= w.window <= 1e7
    # violation shrinks (halve then one linear step keeps it under w0)
    if lat > slo and w0 > 1e-6:
        assert w.window <= w0


@given(w0=st.floats(1.0, 1e4), slo=st.floats(10.0, 1e5),
       n=st.integers(1, 50))
@settings(**SET)
def test_aimd_monotone_growth_under_slo(w0, slo, n):
    w = AIMDWindow(window=w0, unit=5.0, pct=99.0, max_window=1e9)
    prev = w.window
    for _ in range(n):
        w.update(latency=slo * 0.5, slo=slo)   # never violated
        assert w.window >= prev                # linear growth only
        prev = w.window


@given(w=st.floats(1.0, 1e6), u=st.floats(0.0, 1e3),
       lat=st.floats(0.0, 1e7), slo=st.floats(1.0, 1e6))
@settings(**SET)
def test_aimd_jnp_equals_host(w, u, lat, slo):
    host = AIMDWindow(window=w, unit=u, pct=99.0, max_window=1e8)
    host.update(lat, slo)
    wj, uj = aimd_update(jnp.float32(w), jnp.float32(u), jnp.float32(lat),
                         jnp.float32(slo), pct=99.0, max_window=1e8)
    np.testing.assert_allclose(float(wj), host.window, rtol=1e-5)


# ---------------------------------------------------------------------------
# ASL scheduler: no loss, no duplication, bounded bypass
# ---------------------------------------------------------------------------

@given(ops=st.lists(st.tuples(st.sampled_from(["big", "little", "pop",
                                               "tick"]),
                              st.floats(0.0, 5.0)), min_size=1,
                    max_size=60))
@settings(**SET)
def test_scheduler_conservation(ops):
    clk = {"t": 0.0}
    sched = ASLScheduler(lambda: clk["t"], default_window=2.0,
                         max_window=50.0)
    submitted, popped = [], []
    i = 0
    for kind, dt in ops:
        if kind == "tick":
            clk["t"] += dt
        elif kind == "pop":
            it = sched.next_item()
            if it is not None:
                popped.append(it.payload)
        else:
            sched.submit(i, kind)
            submitted.append(i)
            i += 1
    while (it := sched.next_item()) is not None:
        popped.append(it.payload)
    assert sorted(popped) == sorted(submitted)      # exactly once
    assert sched.pending() == 0


@given(n_big=st.integers(0, 10), n_little=st.integers(0, 10))
@settings(**SET)
def test_scheduler_big_fifo_order(n_big, n_little):
    sched = ASLScheduler(lambda: 0.0, default_window=100.0)
    for i in range(n_little):
        sched.submit(("l", i), "little")
    for i in range(n_big):
        sched.submit(("b", i), "big")
    bigs = []
    for _ in range(n_big):
        it = sched.next_item()
        assert it.klass == "big"        # standbys wait out their window
        bigs.append(it.payload[1])
    assert bigs == sorted(bigs)         # FIFO among big


# ---------------------------------------------------------------------------
# Gradient quantization error bound
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2 ** 16), scale=st.floats(1e-3, 1e3),
       n=st.integers(1, 2000))
@settings(**SET)
def test_quantize_error_bound(seed, scale, n):
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (n,))) * scale
    q, s, meta = quantize_int8(jnp.asarray(x), block=256)
    back = np.asarray(dequantize_int8(q, s, meta))
    # per-block bound: half a quantization step of the block max
    blocks = np.pad(np.abs(x), (0, (-n) % 256)).reshape(-1, 256)
    bound = np.repeat(blocks.max(1) / 127.0, 256)[:n] * 0.51 + 1e-9
    assert (np.abs(back - x) <= bound).all()


# ---------------------------------------------------------------------------
# Attention causality: future tokens cannot influence past outputs
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 1000), cut=st.integers(1, 15))
@settings(max_examples=10, deadline=None)
def test_attention_causal_independence(seed, cut):
    b, s, h, kh, dh = 1, 16, 4, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kh, dh))
    v = jax.random.normal(ks[2], (b, s, kh, dh))
    out1 = attention(q, k, v, causal=True, q_block=8, dtype=jnp.float32)
    # perturb k/v strictly after `cut`
    noise = jax.random.normal(ks[3], (b, s - cut, kh, dh)) * 10
    k2 = k.at[:, cut:].add(noise)
    v2 = v.at[:, cut:].add(noise)
    out2 = attention(q, k2, v2, causal=True, q_block=8, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out1[:, :cut]),
                               np.asarray(out2[:, :cut]), atol=1e-5)


# ---------------------------------------------------------------------------
# Data pipeline: host shards tile the global batch for any divisor
# ---------------------------------------------------------------------------

@given(hosts=st.sampled_from([1, 2, 4, 8]), step=st.integers(0, 1000),
       seed=st.integers(0, 100))
@settings(**SET)
def test_data_host_partition_property(hosts, step, seed):
    from repro.data.pipeline import DataConfig, TokenDataset
    full = TokenDataset(DataConfig(vocab=97, seq_len=8, global_batch=8,
                                   seed=seed))
    parts = [TokenDataset(DataConfig(vocab=97, seq_len=8, global_batch=8,
                                     host_index=i, host_count=hosts,
                                     seed=seed)).batch(step)["tokens"]
             for i in range(hosts)]
    np.testing.assert_array_equal(np.concatenate(parts),
                                  full.batch(step)["tokens"])


# ---------------------------------------------------------------------------
# CE loss equals the naive reference
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 1000), v=st.integers(3, 50))
@settings(max_examples=15, deadline=None)
def test_cross_entropy_matches_naive(seed, v):
    from repro.models.lm import cross_entropy
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    logits = jax.random.normal(ks[0], (2, 5, v)) * 5
    labels = jax.random.randint(ks[1], (2, 5), 0, v)
    got = float(cross_entropy(logits, labels))
    lp = jax.nn.log_softmax(np.asarray(logits, np.float64), axis=-1)
    want = -np.mean(np.take_along_axis(
        np.asarray(lp), np.asarray(labels)[..., None], axis=-1))
    assert got == pytest.approx(want, rel=1e-4)
