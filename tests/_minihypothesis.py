"""Minimal, dependency-free stand-in for the slice of `hypothesis` that
`test_properties.py` uses — so the property suite runs even on images
without the real library (declared in requirements-test.txt; this
fallback kicks in only when that install is absent).

Scope: `given(**kwargs)` + `settings(max_examples=, deadline=)` and the
strategies `floats`, `integers`, `lists`, `tuples`, `sampled_from`.
Examples are drawn from a per-test deterministic PRNG (seeded from the
test name, so failures reproduce run-to-run); boundary values are mixed
in with ~15% probability per draw.  No shrinking — the failing example
is reported as-is.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    """Namespace mirror of ``hypothesis.strategies`` (the used subset)."""

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        def draw(rng):
            if rng.random() < 0.15:  # boundary bias
                return rng.choice((min_value, max_value))
            return rng.uniform(min_value, max_value)
        return _Strategy(draw)

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        def draw(rng):
            if rng.random() < 0.15:
                return rng.choice((min_value, max_value))
            return rng.randint(min_value, max_value)
        return _Strategy(draw)

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    @staticmethod
    def tuples(*elems: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    @staticmethod
    def lists(elem: _Strategy, *, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elem.draw(rng) for _ in range(n)]
        return _Strategy(draw)


def settings(max_examples: int = 25, deadline=None):
    """Records run parameters on the test function (deadline is ignored:
    there is no per-example watchdog here)."""
    def deco(fn):
        fn._mh_max_examples = max_examples
        return fn
    return deco


def given(**strategy_kwargs):
    def deco(fn):
        max_examples = getattr(fn, "_mh_max_examples", 25)

        @functools.wraps(fn)
        def runner(*args, **kwargs):
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(max_examples):
                example = {k: s.draw(rng)
                           for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **example, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on example {i}: "
                        f"{example!r}") from e

        # The strategy-filled params must not look like pytest fixtures:
        # present the signature minus the generated arguments.
        params = [p for name, p in
                  inspect.signature(fn).parameters.items()
                  if name not in strategy_kwargs]
        runner.__signature__ = inspect.Signature(params)
        del runner.__wrapped__  # stop inspect from following to fn
        return runner
    return deco
