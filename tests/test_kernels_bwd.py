"""Flash-attention backward kernels vs jax.grad of the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention_bwd import flash_attention_vjp


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kh,s,dh,causal,window", [
    (1, 4, 2, 128, 32, True, 0),      # GQA causal
    (2, 2, 2, 64, 32, True, 0),       # MHA causal
    (1, 2, 1, 128, 64, True, 32),     # MQA + local window
    (1, 2, 2, 64, 32, False, 0),      # bidirectional
])
def test_flash_bwd_matches_ref_grads(b, h, kh, s, dh, causal, window,
                                     dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (b, h, s, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kh, s, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kh, s, dh), jnp.float32).astype(dtype)
    do = jax.random.normal(ks[3], (b, h, s, dh), jnp.float32).astype(dtype)

    def loss_kernel(q, k, v):
        out = flash_attention_vjp(q, k, v, causal, window, 32, 32, True)
        return jnp.sum(out.astype(jnp.float32) * do.astype(jnp.float32))

    def loss_ref(q, k, v):
        out = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
        return jnp.sum(out.astype(jnp.float32) * do.astype(jnp.float32))

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    for name, a, bb in zip("dq dk dv".split(), g1, g2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(bb, np.float32),
                                   atol=tol, rtol=tol, err_msg=name)


def test_flash_vjp_forward_matches_oracle():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    out = flash_attention_vjp(q, k, v, True, 0, 64, 64, True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
