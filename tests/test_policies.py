"""Policy-conformance suite: every policy registered in
repro.core.policies — including future plugins — runs through the
engine's correctness invariants (batched-vs-single parity, sharded
bit-parity, chunked-scan invariance, a no-starvation bound), so a new
~100-line policy plugin gets the full correctness net for free.

Tolerances follow tests/test_sweep.py: parity is *exact* (the batched
masked path runs the same per-event HLO as the single-run switch path).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import columns as colreg
from repro.core import simlock as sl
from repro.core.policies import REGISTRY, get, policy_ids

ALL_POLICIES = tuple(REGISTRY)

# One mid-tension SLO keeps libasl/edf deadlines meaningful without
# special-casing per policy.
SLO_US = 80.0


def _cfg(policy, sim_time_us=6_000.0, **kw):
    return sl.SimConfig(policy=policy, sim_time_us=sim_time_us, **kw)


def _cell(st, i):
    return jax.tree.map(lambda x: np.asarray(x)[i], st)


def _close(got, want):
    assert got["events"] == want["events"]
    np.testing.assert_allclose(got["throughput_cs_per_s"],
                               want["throughput_cs_per_s"], rtol=1e-9)
    assert got["cs_per_core"] == want["cs_per_core"]


# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------

def test_registry_ids_are_stable():
    """The first four ids predate the registry and are load-bearing
    (canonical jit keys, recorded benches); new policies only append."""
    ids = policy_ids()
    assert ids == sl.POLICIES
    for name, want in (("fifo", 0), ("tas", 1), ("prop", 2),
                       ("libasl", 3)):
        assert ids[name] == want
    assert list(ids.values()) == sorted(ids.values())


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_declared_slots_exist(policy):
    """A policy's declared param/table/state slots must resolve against
    the real SimParams/SimTables/SimState (pol-dict slots included)."""
    pol = get(policy)
    cfg = _cfg(policy, sim_time_us=100.0)
    tb = sl.build_tables(cfg)
    pm = sl.build_params(cfg, SLO_US)
    st = sl.init_state(cfg)
    for slot in pol.param_slots:
        name = slot.split("pol.", 1)[-1]
        assert (name in pm.pol if slot.startswith("pol.")
                else hasattr(pm, slot)), slot
    for slot in pol.table_slots:
        # "col.<name>" slots resolve against the registered-column dict.
        if slot.startswith("col."):
            assert slot.split("col.", 1)[1] in tb.col, slot
        else:
            assert hasattr(tb, slot), slot
    for slot in pol.state_slots:
        assert hasattr(st, slot) or slot in st.pol, slot
    for slot in pol.sweep_axes.values():
        assert slot in pm.pol or hasattr(pm, slot), slot


def test_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown lock policy"):
        sl.run(dataclasses.replace(_cfg("fifo"), policy="bogus"), 1e9)


# ---------------------------------------------------------------------------
# Engine invariants, for every registered policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_batched_matches_single(policy):
    """A sweep cell == the dedicated single run, exactly."""
    cfg = _cfg(policy)
    st, grid = sl.sweep(cfg, {"seed": [0, 3]}, slo_us=SLO_US)
    for i, seed in enumerate(grid["seed"]):
        _close(sl.summarize(cfg, _cell(st, i)),
               sl.summarize(cfg, sl.run(cfg, SLO_US, seed=int(seed))))


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_sharded_bit_parity(policy):
    """Sharding the cell axis over the device mesh changes the schedule,
    not one bit of the result (conftest virtualizes 8 host devices)."""
    from repro.launch.mesh import make_sweep_mesh
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 (virtual) device")
    cfg = _cfg(policy, sim_time_us=3_000.0)
    axes = {"seed": [0, 1, 2]}            # non-divisible: pad + trim
    a, _ = sl.sweep(cfg, axes, slo_us=SLO_US)
    b, _ = sl.sweep(cfg, axes, slo_us=SLO_US, mesh=make_sweep_mesh())
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_chunk_invariance(policy):
    """chunk=1 (one event per loop iteration) == chunk=128, exactly."""
    base = _cfg(policy, sim_time_us=3_000.0)
    r1 = sl.run(dataclasses.replace(base, chunk=1), SLO_US, seed=3)
    r128 = sl.run(dataclasses.replace(base, chunk=128), SLO_US, seed=3)
    for x, y in zip(jax.tree.leaves(r1), jax.tree.leaves(r128)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_no_starvation(policy):
    """Bounded reordering everywhere: every active core must retire
    epochs (the paper's starvation-freedom claim; shfl's shuffle bound,
    libasl's max window and prop's ratio all cap the bypassing)."""
    cfg = _cfg(policy, sim_time_us=30_000.0)
    st = sl.run(cfg, SLO_US)
    ep = np.asarray(st.ep_cnt)
    assert (ep > 0).all(), f"{policy}: starved cores {np.where(ep == 0)[0]}"


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_determinism(policy):
    a = sl.run(_cfg(policy, sim_time_us=3_000.0), SLO_US, seed=7)
    b = sl.run(_cfg(policy, sim_time_us=3_000.0), SLO_US, seed=7)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# The two new plugins' policy-specific behavior
# ---------------------------------------------------------------------------

def test_shfl_bound_trades_latency_for_throughput():
    """Bound 0 == FIFO (no shuffling); growing the bound must trade
    little-core latency for throughput monotonically; the bound is a
    traced sweep axis (one executable for the whole curve)."""
    cfg = _cfg("shfl", sim_time_us=10_000.0)
    n0 = sl.n_batch_executables()
    st, grid = sl.sweep(cfg, {"shfl_bound": [0, 4, 64]})
    assert sl.n_batch_executables() - n0 <= 1
    # warmup=8: at bound 64 the starved little cores retire fewer than
    # the default 32 warmup samples — the fixed _ring_values correctly
    # reports an empty (nan) tail there, so trim less for this check.
    rows = sl.sweep_summaries(cfg, st, grid, warmup=8)
    fifo = sl.summarize(cfg, sl.run(_cfg("fifo", sim_time_us=10_000.0),
                                    1e9))
    assert rows[0]["throughput_cs_per_s"] == pytest.approx(
        fifo["throughput_cs_per_s"], rel=0.02)
    tput = [r["throughput_cs_per_s"] for r in rows]
    lat = [r["ep_p99_little_us"] for r in rows]
    assert tput[0] < tput[1] < tput[2]
    assert lat[0] < lat[1] < lat[2]


def test_edf_orders_by_deadline():
    """A tight-SLO core class must see lower tail latency than a loose
    one under edf (the slo_scale table drives the deadline order)."""
    cfg = _cfg("edf", sim_time_us=20_000.0,
               slo_scale=(4.0, 4.0, 4.0, 4.0, 1.0, 1.0, 1.0, 1.0))
    s = sl.summarize(cfg, sl.run(cfg, 50.0))
    # little cores carry the tight SLO here: their grants must come
    # early enough that their (slower) epochs do not trail far behind
    # the loose-SLO big cores despite the 3.75x CS handicap.
    assert s["ep_p99_little_us"] < 2.0 * s["ep_p99_big_us"]


def test_edf_huge_slo_degrades_to_arrival_order_not_index_bias():
    """The 'pure-throughput' SLO convention (1e9) must not collapse edf
    into core-index bias: exact i32 deadlines (clamped at the
    max_window starvation cap) + arrival-order tie-break keep equal
    cores near-equal (a f32 deadline would quantize at 8192-tick ulp
    and argmin would then always favor low indices)."""
    cfg = _cfg("edf", sim_time_us=30_000.0)
    s = sl.summarize(cfg, sl.run(cfg, 1e9))
    big = np.asarray(s["cs_per_core"][:4], float)
    assert big.max() / big.min() < 1.35, big


def test_policy_kw_typo_raises():
    cfg = _cfg("shfl", policy_kw=(("shfl_bnd", 0),))     # typo'd knob
    with pytest.raises(ValueError, match="unknown policy_kw"):
        sl.run(cfg, 1e9)
    with pytest.raises(ValueError, match="unknown policy_kw"):
        sl.run(_cfg("fifo", policy_kw=(("shfl_bound", 1),)), 1e9)


def test_shfl_starvation_bound_zero_is_fifo_exact():
    """bound=0 never bypasses the head: grant counts match fifo."""
    shfl = _cfg("shfl", sim_time_us=8_000.0, policy_kw=(("shfl_bound", 0),))
    fifo = _cfg("fifo", sim_time_us=8_000.0)
    a = sl.summarize(shfl, sl.run(shfl, 1e9))
    b = sl.summarize(fifo, sl.run(fifo, 1e9))
    assert a["cs_per_core"] == b["cs_per_core"]


# ---------------------------------------------------------------------------
# Open-loop arrivals (wl_open): arrivals as events, every policy
# ---------------------------------------------------------------------------

def _open_cfg(policy, rate=0.1, sim_time_us=6_000.0):
    return sl.SimConfig(policy=policy, wl=True, wl_open=True,
                        wl_process="poisson", wl_rate=rate,
                        sim_time_us=sim_time_us)


@pytest.mark.parametrize("policy", ("fifo", "libasl", "shfl"))
def test_open_loop_chunk_invariance(policy):
    base = _open_cfg(policy)
    r1 = sl.run(dataclasses.replace(base, chunk=1), SLO_US, seed=2)
    r128 = sl.run(dataclasses.replace(base, chunk=128), SLO_US, seed=2)
    for x, y in zip(jax.tree.leaves(r1), jax.tree.leaves(r128)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_open_loop_batched_matches_single():
    cfg = _open_cfg("libasl")
    st, grid = sl.sweep(cfg, {"arrival_rate": [0.05, 0.15]}, slo_us=SLO_US)
    for i, rate in enumerate(grid["arrival_rate"]):
        single = sl.run(dataclasses.replace(cfg, wl_rate=float(rate)),
                        SLO_US)
        _close(sl.summarize(cfg, _cell(st, i)),
               sl.summarize(cfg, single))


def test_open_loop_latency_diverges_past_saturation():
    """The open-loop signature the closed loop cannot show: past lock
    saturation the sojourn tail keeps growing with offered load (the
    backlog is real work, not self-throttled think time)."""
    from benchmarks.paper_figs import _openloop_rate
    rates = [_openloop_rate(f) for f in (0.3, 2.0)]
    cfg = _open_cfg("fifo", sim_time_us=30_000.0)
    st, _ = sl.sweep(cfg, {"arrival_rate": rates}, slo_us=1e9)
    lo = sl.summarize(cfg, _cell(st, 0))
    hi = sl.summarize(cfg, _cell(st, 1))
    assert hi["ep_p99_all_us"] > 3.0 * lo["ep_p99_all_us"]
    # underload must NOT queue: sojourn stays near the no-contention
    # epoch length (noncrit + cs, well under one SLO)
    assert lo["ep_p99_all_us"] < 1_000.0


# ---------------------------------------------------------------------------
# Fault injection (repro.faults): the conformance net under chaos —
# every registered policy keeps its engine invariants with preemption,
# churn and straggler spikes enabled, and zero-rate injection is
# provably a no-op.
# ---------------------------------------------------------------------------

FAULT_KW = dict(preempt_rate=0.1, preempt_scale_us=30.0,
                churn_rate=0.2, churn_period_us=200.0,
                straggle_rate=0.05, straggle_scale=10.0)


def _fault_cfg(policy, sim_time_us=6_000.0, **kw):
    return _cfg(policy, sim_time_us=sim_time_us, **{**FAULT_KW, **kw})


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_faulted_batched_matches_single(policy):
    """Fault draws are counter-pure per (core, CS index): a faulted
    sweep cell == the dedicated faulted single run, exactly."""
    cfg = _fault_cfg(policy)
    st, grid = sl.sweep(cfg, {"seed": [0, 3]}, slo_us=SLO_US)
    for i, seed in enumerate(grid["seed"]):
        _close(sl.summarize(cfg, _cell(st, i)),
               sl.summarize(cfg, sl.run(cfg, SLO_US, seed=int(seed))))


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_faulted_chunk_invariance(policy):
    cfg = _fault_cfg(policy, sim_time_us=3_000.0)
    r1 = sl.run(dataclasses.replace(cfg, chunk=1), SLO_US, seed=3)
    r128 = sl.run(dataclasses.replace(cfg, chunk=128), SLO_US, seed=3)
    for x, y in zip(jax.tree.leaves(r1), jax.tree.leaves(r128)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_no_deadlock_no_starvation_under_faults(policy):
    """Liveness under combined chaos: a churned-out core always rejoins
    (finite t_ready), a preempted holder always releases — every core
    keeps retiring epochs and the sim reaches its horizon."""
    cfg = _fault_cfg(policy, sim_time_us=30_000.0)
    st = sl.run(cfg, SLO_US)
    s = sl.summarize(cfg, st)
    ep = np.asarray(st.ep_cnt)
    assert (ep > 0).all(), f"{policy}: starved cores {np.where(ep == 0)[0]}"
    assert s["sim_time_us"] >= 0.9 * cfg.sim_time_us
    assert s["events"] < cfg.max_events


@pytest.mark.parametrize("policy", ("fifo", "libasl"))
def test_zero_rate_faults_bit_identical(policy):
    """Gate-on, rate-zero injection == fault-free run, bit for bit (the
    additive-where fault arithmetic cannot perturb a zero-rate run)."""
    plain = _cfg(policy, sim_time_us=3_000.0)
    st_plain = sl.run(plain, SLO_US, seed=1)
    # sweep() flips the static gates on (the axes reach nonzero values);
    # cell 0 runs every rate at 0.0.
    st_sw, _ = sl.sweep(plain, {"preempt_rate": [0.0, 0.1],
                                "churn_rate": [0.0, 0.2],
                                "straggle_rate": [0.0, 0.05]},
                        product=False, slo_us=SLO_US, seed=1)
    for x, y in zip(jax.tree.leaves(_cell(st_sw, 0)),
                    jax.tree.leaves(st_plain)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_all_zero_fault_mask_bit_identical():
    """ft_mask multiplies the rates: an all-zero eligibility mask turns
    nonzero fault rates into a bit-exact no-op."""
    plain = _cfg("fifo", sim_time_us=3_000.0)
    masked = _fault_cfg("fifo", sim_time_us=3_000.0,
                        fault_mask=(0.0,) * plain.n_cores,
                        churn_rate=0.0)   # churn keys off t, not ft_mask
    a = sl.run(plain, SLO_US, seed=2)
    b = sl.run(masked, SLO_US, seed=2)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_preemption_asymmetry_mask_spares_big_cores():
    """fault_mask picks the victims: with only little cores eligible,
    big-core-affine grants dodge every stall — throughput under heavy
    preemption must beat the all-cores-eligible run."""
    little_only = _fault_cfg("fifo", sim_time_us=20_000.0,
                             preempt_rate=0.3, churn_rate=0.0,
                             straggle_rate=0.0,
                             fault_mask=(0.0,) * 4 + (1.0,) * 4)
    all_cores = _fault_cfg("fifo", sim_time_us=20_000.0,
                           preempt_rate=0.3, churn_rate=0.0,
                           straggle_rate=0.0)
    a = sl.summarize(little_only, sl.run(little_only, 1e9))
    b = sl.summarize(all_cores, sl.run(all_cores, 1e9))
    assert a["throughput_cs_per_s"] > b["throughput_cs_per_s"]


def test_preemption_craters_fifo_throughput():
    """The chaos_collapse headline: preemption stalls land on the whole
    FIFO convoy, so throughput must drop steeply with the rate."""
    cfg = _cfg("fifo", sim_time_us=20_000.0, preempt_rate=0.2,
               preempt_scale_us=50.0)
    st, grid = sl.sweep(cfg, {"preempt_rate": [0.0, 0.2]}, slo_us=1e9)
    rows = sl.sweep_summaries(cfg, st, grid)
    assert rows[1]["throughput_cs_per_s"] < \
        0.7 * rows[0]["throughput_cs_per_s"]


def test_goodput_metric():
    """summarize(slo_us=...) reports the SLO-met fraction and scales
    throughput by it; an infinite SLO makes goodput == throughput."""
    cfg = _cfg("fifo", sim_time_us=6_000.0)
    st = sl.run(cfg, 1e9)
    s = sl.summarize(cfg, st, slo_us=1e12)
    assert s["slo_good_frac"] == 1.0
    assert s["goodput_eps"] == s["throughput_epochs_per_s"]
    tight = sl.summarize(cfg, st, slo_us=1e-6)
    assert tight["slo_good_frac"] == 0.0
    assert tight["goodput_eps"] == 0.0


def test_open_loop_arrivals_policy_independent():
    """Open-loop discipline: the arrival stream is workload state —
    counter-pure draws the policy under test cannot perturb.  At deep
    underload every policy retires the same arrivals by the horizon, so
    both the per-core epoch counts and the pending next-arrival times
    must agree bit-exactly across policies."""
    out = {}
    for policy in ("fifo", "shfl"):
        st = sl.run(_open_cfg(policy, rate=0.02, sim_time_us=20_000.0),
                    SLO_US)
        out[policy] = (np.asarray(st.ep_cnt).copy(),
                       np.asarray(st.arr_t).copy())
    np.testing.assert_array_equal(out["fifo"][0], out["shfl"][0])
    np.testing.assert_array_equal(out["fifo"][1], out["shfl"][1])


# ---------------------------------------------------------------------------
# Policy-owned SimTables columns (repro.core.columns): conformance for
# the declared-column mechanism every feature layer now rides on.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_own_columns_registered_and_sweepable(policy):
    """Every declared own_column (and every "col." table slot) must be
    a registered ColumnSpec, and sweepable specs must surface as sweep
    axes for the policy's configs."""
    pol = get(policy)
    axes = sl.sweepable_axes(_cfg(policy, sim_time_us=100.0))
    for name in pol.own_columns:
        spec = colreg.COLUMNS[name]
        if spec.sweepable:
            assert spec.axis in axes, name
    for slot in pol.table_slots:
        if slot.startswith("col."):
            assert slot.split("col.", 1)[1] in colreg.COLUMNS, slot


def test_owned_column_sweeps_in_one_executable():
    """dvfs_race's own ``race_w`` column batches as a table sweep axis:
    one executable for the whole curve, each cell == its dedicated
    single run (set via with_columns)."""
    cfg = _cfg("dvfs_race")
    tables = [(1.0,) * 8, (1.0,) * 4 + (0.0,) * 4, (3.0,) + (1.0,) * 7]
    n0 = sl.n_batch_executables()
    st, grid = sl.sweep(cfg, {"race_w": tables}, slo_us=SLO_US)
    assert sl.n_batch_executables() - n0 <= 1
    for i, tab in enumerate(grid["race_w"]):
        single = sl.run(sl.with_columns(cfg, race_w=tuple(tab)), SLO_US)
        _close(sl.summarize(cfg, _cell(st, i)), sl.summarize(cfg, single))


def test_owned_column_sharded_bit_parity():
    from repro.launch.mesh import make_sweep_mesh
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 (virtual) device")
    cfg = _cfg("dvfs_race", sim_time_us=3_000.0)
    tables = [(1.0,) * 8, (2.0,) * 4 + (1.0,) * 4, (0.5,) * 8]
    a, _ = sl.sweep(cfg, {"race_w": tables}, slo_us=SLO_US)
    b, _ = sl.sweep(cfg, {"race_w": tables}, slo_us=SLO_US,
                    mesh=make_sweep_mesh())
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_canon_wipes_columns_from_jit_key():
    """Round-trip: two configs differing only in column values share one
    canonical jit key (columns are traced, not static), canon is
    idempotent, and the one static energy bit survives canon."""
    base = _cfg("dvfs_race")
    varied = sl.with_columns(base, race_w=(2.0,) * 8,
                             slo_scale=(1.0, 4.0) * 4,
                             dvfs=(1.5,) * 8)
    assert sl._canon(varied) == sl._canon(base)
    assert sl._canon(sl._canon(varied)) == sl._canon(varied)
    assert sl._canon(varied).columns == ()
    powered = sl.with_columns(base, p_cs=(1.0,) * 8)
    assert sl._canon(powered) != sl._canon(base)
    assert sl._canon(sl.with_columns(base, p_idle=(2.0,) * 8)) == \
        sl._canon(powered)


def test_unknown_column_did_you_mean():
    with pytest.raises(ValueError, match="did you mean 'race_w'"):
        sl.with_columns(_cfg("dvfs_race", sim_time_us=100.0),
                        race_weight=((1.0,) * 8))
    with pytest.raises(ValueError, match="unknown SimTables column"):
        sl.SimConfig(policy="fifo", columns=(("no_such_col", (1.0,)),))
    # dedicated-field columns must ride their field, not the generic
    # tuple (two sources of truth would desync).
    with pytest.raises(ValueError, match="dedicated SimConfig field"):
        sl.SimConfig(policy="fifo", columns=(("slo_scale", (1.0,)),))


def test_dvfs_race_prefers_fast_cores():
    """Race-to-idle granting must beat FIFO throughput on the default
    4+4 AMP (big-forward, like shfl) while the race_bound cap keeps
    every little core live."""
    race = _cfg("dvfs_race", sim_time_us=10_000.0)
    fifo = _cfg("fifo", sim_time_us=10_000.0)
    a = sl.summarize(race, sl.run(race, 1e9))
    b = sl.summarize(fifo, sl.run(fifo, 1e9))
    assert a["throughput_cs_per_s"] > b["throughput_cs_per_s"]


def test_race_w_zero_still_live():
    """race_w=0 bans shuffling entirely — the forced-head fallback must
    still grant every waiter (liveness under a degenerate column)."""
    cfg = sl.with_columns(_cfg("dvfs_race", sim_time_us=20_000.0),
                          race_w=(0.0,) * 8)
    st = sl.run(cfg, SLO_US)
    assert (np.asarray(st.ep_cnt) > 0).all()


# ---------------------------------------------------------------------------
# Streaming histograms (cfg.hist) — conformance for every policy
# ---------------------------------------------------------------------------

def _hist_runs(policy):
    cfg_off = _cfg(policy, sim_time_us=3_000.0)
    cfg_on = dataclasses.replace(cfg_off, hist=True)
    return (cfg_off, sl.run(cfg_off, SLO_US, seed=3),
            cfg_on, sl.run(cfg_on, SLO_US, seed=3))


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_hist_gate_is_passive(policy):
    """Flipping the histogram gate on must not move one bit of any
    pre-existing state leaf (recording is purely additive), and
    gate-off leaves the hist leaves minimal and untouched."""
    _, off, _, on = _hist_runs(policy)
    for f in off._fields:
        if f in ("ep_hist", "cs_hist"):
            continue
        a, b = getattr(off, f), getattr(on, f)
        if isinstance(a, dict):
            for k in a:
                np.testing.assert_array_equal(
                    np.asarray(a[k]), np.asarray(b[k]), err_msg=f"{f}.{k}")
        else:
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f)
    assert off.ep_hist.shape[1] == 1 and not np.asarray(off.ep_hist).any()
    assert on.ep_hist.shape[1] == sl.SimConfig().hist_buckets


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_hist_quantile_within_bound(policy):
    """On an un-wrapped run the histogram quantiles must sit within the
    documented one-bucket relative-error bound of the exact ring
    percentiles, per core class, for every registered policy (the
    device warmup gate defaults to summarize's ring warmup, so the two
    paths see the same sample set)."""
    _, _, cfg_on, on = _hist_runs(policy)
    assert (np.asarray(on.ep_cnt) <= cfg_on.epcap).all()  # un-wrapped
    s = sl.summarize(cfg_on, on, slo_us=SLO_US)
    bound = s["hist_rel_err_bound"]
    for fam in ("ep_p99", "ep_p50", "cs_p99"):
        for cls in ("all", "big", "little"):
            exact = s[f"{fam}_{cls}_us"]
            est = s[f"{fam}_hist_{cls}_us"]
            if np.isnan(exact):
                assert np.isnan(est), (fam, cls)
                continue
            assert abs(est - exact) <= bound * exact + 1e-9, \
                (fam, cls, est, exact, bound)
    # Histogram goodput within one bucket's mass of the exact fraction.
    assert abs(s["slo_good_frac_hist"] - s["slo_good_frac"]) < 0.05
