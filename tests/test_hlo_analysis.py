"""HLO accounting unit tests: the roofline's methodological premises.

1. XLA's ``cost_analysis`` counts a ``lax.scan`` body ONCE — the reason
   ``benchmarks/roofline.py`` measures unrolled-shallow variants and
   extrapolates by depth (the claim EXPERIMENTS.md cites).
2. ``collective_stats`` parses both ``replica_groups`` spellings and
   applies the ring-algorithm link-byte factors.
3. ``analytic_hbm_bytes(rules=...)`` shards each traffic component by
   its actual shard count on the mesh.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.dist.hlo_analysis import (analytic_hbm_bytes, collective_stats,
                                     xla_cost)
from repro.dist.sharding import build_rules
from repro.launch.mesh import make_mesh


def _flops(fn, *args):
    return xla_cost(jax.jit(fn).lower(*args).compile()).get("flops", 0.0)


def test_scan_body_counted_once():
    """A 10-step scan's flops read ~1/10th of the unrolled loop's — the
    while-body-counted-once behaviour the depth finite-difference in
    benchmarks/roofline.py corrects for."""
    x = jnp.ones((64, 64), jnp.float32)
    steps = 10

    def body(c, _):
        return c @ c, None

    def rolled(c):
        return jax.lax.scan(body, c, None, length=steps)[0]

    def unrolled(c):
        for _ in range(steps):
            c = c @ c
        return c

    f_roll, f_unroll = _flops(rolled, x), _flops(unrolled, x)
    assert f_roll > 0 and f_unroll > 0
    assert f_unroll / f_roll == pytest.approx(steps, rel=0.01)


def test_collective_stats_ring_link_bytes():
    hlo = "\n".join([
        # all-reduce, 1024 f32 over explicit groups of 4:
        #   link = 4096 B * 2*(4-1)/4 = 6144
        "  %ar = f32[1024]{0} all-reduce(%x), "
        "replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add",
        # all-gather, bf16 result 512 elems over iota groups of 8:
        #   link = 1024 B * (8-1)/8 = 896
        "  %ag = bf16[512]{0} all-gather(%y), "
        "replica_groups=[2,8]<=[16], dimensions={0}",
        # reduce-scatter shard 256 f32 over groups of 4: 1024 B * 3 = 3072
        "  %rs = f32[256]{0} reduce-scatter(%z), "
        "replica_groups=[4,4]<=[16], to_apply=%add",
        # -done lines must not double-count
        "  %d = f32[1024]{0} all-reduce-done(%ar)",
    ])
    st = collective_stats(hlo)
    assert st["all-reduce"] == {
        "count": 1, "bytes": 4096.0, "link_bytes": 6144.0}
    assert st["all-gather"] == {
        "count": 1, "bytes": 1024.0, "link_bytes": 896.0}
    assert st["reduce-scatter"] == {
        "count": 1, "bytes": 1024.0, "link_bytes": 3072.0}
    assert st["total_count"] == 3
    assert st["total"] == {"count": 3, "bytes": 6144.0,
                           "link_bytes": 10112.0}
    assert set(st["ops"]) == {"all-reduce", "all-gather", "reduce-scatter"}


def test_collective_stats_group_size_fallback():
    hlo = "  %ar = f32[1024]{0} all-reduce(%x), to_apply=%add"
    # no replica_groups: n_devices fallback sets the ring factor
    assert collective_stats(hlo, 4)["total"]["link_bytes"] == 6144.0
    # without a fallback the op is counted but moves no link bytes
    assert collective_stats(hlo)["total"]["link_bytes"] == 0.0
    assert collective_stats(hlo)["total_count"] == 1


def test_analytic_hbm_bytes_sharded():
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 (virtual) devices")
    from repro.configs import registry
    from repro.models.config import standard_shapes

    cfg, meta = registry.get("yi-6b")
    shapes = standard_shapes(meta.train_microbatches)
    mesh = make_mesh((4, 2), ("data", "model"))

    for shape_name in ("train_4k", "decode_32k"):
        shape = shapes[shape_name]
        rules = build_rules(mesh, kv_heads=cfg.n_kv_heads,
                            n_experts=cfg.n_experts, step=shape.kind,
                            seq_parallel=cfg.seq_parallel)
        glob = analytic_hbm_bytes(cfg, shape)
        per_dev = analytic_hbm_bytes(cfg, shape, rules)
        # sharding strictly reduces per-device traffic, and can cut it at
        # most n_devices-fold
        assert glob / 8 <= per_dev < glob

    # decode: batch replicates (weight-stationary), so activations don't
    # shard — per-device traffic exceeds global/8
    shape = shapes["decode_32k"]
    rules = build_rules(mesh, kv_heads=cfg.n_kv_heads,
                        n_experts=cfg.n_experts, step="decode",
                        seq_parallel=cfg.seq_parallel)
    assert rules.num_shards("batch") == 1
    assert rules.num_shards("cache_batch") == 4
