"""Behavioral tests of the JAX discrete-event AMP simulator against the
paper's qualitative claims (the quantitative figures live in benchmarks/)."""

import jax
import numpy as np
import pytest

from repro.core import simlock as sl


def _run(policy, slo=1e9, **kw):
    cfg = sl.SimConfig(policy=policy, sim_time_us=30_000.0, **kw)
    st = sl.run(cfg, slo)
    return cfg, sl.summarize(cfg, st)


def test_fifo_equal_cs_counts():
    """FIFO gives every core an equal chance to lock (Implication 1)."""
    _, s = _run("fifo")
    cs = np.array(s["cs_per_core"], float)
    assert cs.min() > 0
    assert cs.max() / cs.min() < 1.35   # near-equal grants


def test_fifo_throughput_collapse_vs_big_only():
    """MCS throughput collapses when little cores join (paper Figure 1)."""
    _, s8 = _run("fifo", seg_noncrit_us=(1.0,), seg_cs_us=(3.0,),
                 inter_epoch_us=5.0)
    cfg4 = sl.SimConfig(policy="fifo", n_cores=4, big=(1,) * 4,
                        speed_cs=(1.0,) * 4, speed_nc=(1.0,) * 4,
                        sim_time_us=30_000.0)
    s4 = sl.summarize(cfg4, sl.run(cfg4, 1e9))
    assert s8["throughput_cs_per_s"] < 0.6 * s4["throughput_cs_per_s"]


def test_tas_little_affinity_collapses_big_latency():
    """Little-core-affinity TAS: big cores starve (paper Figure 1/3b)."""
    _, s = _run("tas", w_big=0.15)
    assert s["cs_p99_big_us"] > 2.5 * s["cs_p99_little_us"]
    cs = np.array(s["cs_per_core"], float)
    assert cs[4:].sum() > 1.5 * cs[:4].sum()   # most CS on little cores


def test_tas_big_affinity_faster_but_unfair():
    """Big-core-affinity TAS: higher throughput than FIFO, latency collapse
    on little cores (paper Figure 4)."""
    _, sf = _run("fifo")
    _, st = _run("tas", w_big=8.0)
    assert st["throughput_cs_per_s"] > 1.1 * sf["throughput_cs_per_s"]
    assert st["cs_p99_little_us"] > 2.0 * sf["cs_p99_little_us"]


def test_proportional_tradeoff_monotonic():
    """Larger proportion => more throughput and longer little-core latency
    (paper Figure 5)."""
    tput, lat = [], []
    for n in (1, 5, 20):
        _, s = _run("prop", prop_n=n)
        tput.append(s["throughput_cs_per_s"])
        lat.append(s["ep_p99_little_us"])
    assert tput[0] < tput[1] < tput[2]
    assert lat[0] < lat[1] < lat[2]


def test_libasl_fallback_to_fifo_at_zero_slo():
    """SLO=0 is unachievable -> LibASL == FIFO (paper LibASL-0)."""
    _, sf = _run("fifo")
    _, s0 = _run("libasl", slo=0.0)
    assert s0["throughput_cs_per_s"] == pytest.approx(
        sf["throughput_cs_per_s"], rel=0.05)
    w = np.array(s0["final_window_us"][4:])
    assert (w < 1.0).all()          # windows collapsed


def test_libasl_tracks_slo():
    """Little-core P99 epoch latency sticks just under the SLO while
    throughput exceeds FIFO (paper Figure 8b)."""
    _, sf = _run("fifo")
    for slo in (60.0, 90.0):
        _, s = _run("libasl", slo=slo)
        assert s["ep_p99_little_us"] <= slo * 1.15
        assert s["ep_p99_little_us"] >= slo * 0.5
        assert s["throughput_cs_per_s"] > sf["throughput_cs_per_s"]


def test_libasl_throughput_monotonic_in_slo():
    ts = []
    for slo in (40.0, 80.0, 160.0):
        _, s = _run("libasl", slo=slo)
        ts.append(s["throughput_cs_per_s"])
    assert ts[0] <= ts[1] * 1.02 and ts[1] <= ts[2] * 1.02
    assert ts[2] > ts[0]


def test_determinism():
    cfg = sl.SimConfig(policy="libasl", sim_time_us=10_000.0)
    a = sl.run(cfg, 50.0, seed=7)
    b = sl.run(cfg, 50.0, seed=7)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_sweep_vmap_matches_single():
    cfg = sl.SimConfig(policy="libasl", sim_time_us=10_000.0)
    sweep = sl.sweep_slo(cfg, [30.0, 70.0])
    single = sl.run(cfg, jax.numpy.float32(70.0))
    sv = sl.summarize(cfg, jax.tree.map(lambda x: x[1], sweep))
    ss = sl.summarize(cfg, single)
    assert sv["throughput_cs_per_s"] == pytest.approx(
        ss["throughput_cs_per_s"], rel=1e-6)


def test_two_locks_program():
    """Bench-1 shape: 4 CS of different lengths over 2 locks per epoch."""
    cfg = sl.SimConfig(policy="libasl", n_locks=2,
                       seg_noncrit_us=(1.0, 0.5, 0.5, 0.5),
                       seg_cs_us=(2.0, 1.0, 3.0, 0.5),
                       seg_lock=(0, 1, 0, 1),
                       sim_time_us=20_000.0)
    st = sl.run(cfg, 200.0)
    s = sl.summarize(cfg, st)
    assert s["throughput_cs_per_s"] > 0
    assert np.isfinite(s["ep_p99_little_us"])
    # conservation: every epoch contains 4 critical sections
    assert sum(s["cs_per_core"]) == pytest.approx(
        4 * sum(s["epochs_per_core"]), abs=4 * cfg.n_cores)
