"""Substrate tests: checkpointing, data pipeline, trainer restart,
gradient compression, bounded staleness, serving schedulers."""

import os
import signal
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpointer as ck
from repro.configs import registry
from repro.core.asl_schedule import ASLScheduler, FIFOScheduler, GreedyScheduler
from repro.data.pipeline import DataConfig, PrefetchLoader, TokenDataset
from repro.dist.staleness import BoundedStalenessController, simulate
from repro.optim.compression import (compress_tree, decompress_tree,
                                     quantize_int8, dequantize_int8)
from repro.train.trainer import Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# Checkpointer
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(6, dtype=jnp.int32)},
            "l": [jnp.ones((2,)), jnp.zeros((3, 3))]}


def test_ckpt_roundtrip(tmp_path):
    t = _tree()
    ck.save(tmp_path, 7, t)
    assert ck.latest_step(tmp_path) == 7
    out = ck.restore(tmp_path, 7, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_atomic_no_partial(tmp_path):
    """A leftover .tmp dir is never visible as a restorable step."""
    t = _tree()
    ck.save(tmp_path, 3, t)
    (tmp_path / "step_9.tmp").mkdir()
    (tmp_path / "step_9.tmp" / "junk.npy").write_bytes(b"xx")
    assert ck.latest_step(tmp_path) == 3


def test_ckpt_manager_keep_policy(tmp_path):
    m = ck.CheckpointManager(tmp_path, keep=2, save_async=False)
    for s in (1, 2, 3, 4):
        m.save(s, _tree(s))
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir())
    assert steps == [3, 4]


def test_ckpt_reshard_restore(tmp_path):
    """Restore device_puts against new shardings (elastic re-mesh path)."""
    t = _tree()
    ck.save(tmp_path, 1, t)
    sh = jax.tree.map(
        lambda x: jax.sharding.SingleDeviceSharding(jax.devices()[0]), t)
    out = ck.restore(tmp_path, 1, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t), sh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restart_safe():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=4)
    ds = TokenDataset(cfg)
    b1 = ds.batch(5)
    b2 = ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    # next-token alignment
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # different steps differ
    assert not np.array_equal(ds.batch(6)["tokens"], b1["tokens"])


def test_data_host_sharding_partitions():
    full = TokenDataset(DataConfig(vocab=53, seq_len=8, global_batch=8))
    h0 = TokenDataset(DataConfig(vocab=53, seq_len=8, global_batch=8,
                                 host_index=0, host_count=2))
    h1 = TokenDataset(DataConfig(vocab=53, seq_len=8, global_batch=8,
                                 host_index=1, host_count=2))
    f = full.batch(3)["tokens"]
    np.testing.assert_array_equal(
        np.concatenate([h0.batch(3)["tokens"], h1.batch(3)["tokens"]]), f)


def test_prefetch_loader():
    ds = TokenDataset(DataConfig(vocab=31, seq_len=8, global_batch=2))
    loader = PrefetchLoader(ds, start_step=0, prefetch=2)
    steps = [next(loader)[0] for _ in range(5)]
    loader.close()
    assert steps == [0, 1, 2, 3, 4]


# ---------------------------------------------------------------------------
# Trainer: restart determinism + preemption
# ---------------------------------------------------------------------------

def _mk_trainer(tmp_path, total=12, every=4):
    cfg = registry.get_tiny("yi_6b")
    return Trainer(cfg, TrainerConfig(
        total_steps=total, ckpt_every=every, ckpt_dir=str(tmp_path),
        keep=10, lr=1e-3, global_batch=4, seq_len=32))


def test_trainer_restart_bit_identical(tmp_path):
    t1 = _mk_trainer(tmp_path / "a")
    out1 = t1.run()
    # interrupted run: 6 steps (checkpoint at the interruption boundary),
    # then a fresh Trainer restores and finishes — losses per step and the
    # final params must match the uninterrupted run exactly.
    t2 = _mk_trainer(tmp_path / "b")
    t2.run(max_steps=6)
    t3 = _mk_trainer(tmp_path / "b")
    out3 = t3.run()
    l1 = {h["step"]: h["loss"] for h in out1["history"]}
    l3 = {h["step"]: h["loss"] for h in out3["history"]}
    for s in (7, 8, 12):
        assert l1[s] == pytest.approx(l3[s], rel=1e-6), (s, l1[s], l3[s])
    for a, b in zip(jax.tree.leaves(out1["params"]),
                    jax.tree.leaves(out3["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-6)


def test_trainer_preemption_checkpoints(tmp_path):
    t = _mk_trainer(tmp_path, total=50, every=100)
    t._preempted = False

    def preempt_soon():
        import time
        time.sleep(0.5)
        t._preempted = True

    th = threading.Thread(target=preempt_soon)
    th.start()
    out = t.run()
    th.join()
    assert out["preempted"]
    assert t.ckpt.latest() == out["step"]    # checkpointed at the boundary


# ---------------------------------------------------------------------------
# Gradient compression w/ error feedback
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_small():
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (1000,)) * 3.0
    q, s, meta = quantize_int8(x)
    back = dequantize_int8(q, s, meta)
    assert float(jnp.max(jnp.abs(back - x))) < 3.0 / 127 * 1.01 * 3


def test_error_feedback_unbiased_over_steps():
    """With EF, the *cumulative* compressed sum tracks the true sum."""
    k = jax.random.PRNGKey(1)
    true_sum = jnp.zeros((256,))
    ef_sum = jnp.zeros((256,))
    err = None
    for i in range(50):
        g = {"w": jax.random.normal(jax.random.fold_in(k, i), (256,))}
        payload, err = compress_tree(g, err)
        deq = decompress_tree(payload, g)
        true_sum = true_sum + g["w"]
        ef_sum = ef_sum + deq["w"]
    # residual is bounded by one quantization step, not growing with steps
    resid = float(jnp.max(jnp.abs(true_sum - ef_sum)))
    assert resid < 0.2, resid


# ---------------------------------------------------------------------------
# Bounded staleness (straggler mitigation)
# ---------------------------------------------------------------------------

def test_staleness_window_zero_is_synchronous():
    ctl = BoundedStalenessController(4, window_steps=0.0)
    assert ctl.can_commit(0)
    ctl.commit(0)
    assert not ctl.can_commit(0)    # must wait for everyone
    for p in (1, 2, 3):
        ctl.commit(p)
    assert ctl.can_commit(0)


def test_staleness_bounded_by_window():
    ctl = BoundedStalenessController(2, window_steps=3.0)
    for _ in range(3):
        assert ctl.can_commit(0)
        ctl.commit(0)
    assert not ctl.can_commit(0)
    assert ctl.staleness() == 3


def test_straggler_sim_throughput_gain_with_quality_bound():
    """Transient stragglers (10% of steps 5x slower): bounded staleness
    absorbs them; synchronous training stalls everyone on every blip."""
    dur = [1.0] * 8
    kw = dict(straggle_prob=0.1, straggle_factor=5.0, seed=11)
    sync, _, _ = simulate(8, dur,
                          controller=BoundedStalenessController(
                              8, window_steps=0.0, max_window=0.0), **kw)
    ctl = BoundedStalenessController(8, window_steps=4.0, max_window=8.0)
    sps, mean_st, p99_st = simulate(8, dur, controller=ctl,
                                    quality_slo=6.0, penalty_per_stale=1.0,
                                    **kw)
    assert sps > 1.15 * sync         # throughput win on transients
    assert p99_st <= 8.0             # bounded (starvation-free analogue)


def test_straggler_sim_permanent_straggler_no_win():
    """With a permanently slow pod, every bounded policy converges to the
    slowest rate — documents the window's quality-bound semantics."""
    dur = [1.0, 1.0, 1.0, 2.0]
    sync, _, _ = simulate(4, dur,
                          controller=BoundedStalenessController(
                              4, window_steps=0.0, max_window=0.0))
    ctl = BoundedStalenessController(4, window_steps=4.0, max_window=8.0)
    sps, _, p99_st = simulate(4, dur, controller=ctl, quality_slo=6.0)
    assert sps == pytest.approx(sync, rel=0.15)
    assert p99_st <= 8.0


# ---------------------------------------------------------------------------
# ASL scheduler unit behavior
# ---------------------------------------------------------------------------

def test_schedulers_ordering():
    clk = {"t": 0.0}
    c = lambda: clk["t"]
    fifo, greedy = FIFOScheduler(c), GreedyScheduler(c)
    asl = ASLScheduler(c, default_window=10.0, max_window=100.0)
    for s in (fifo, greedy, asl):
        s.submit("p1", "little")
        s.submit("d1", "big")
    assert fifo.next_item().payload == "p1"      # arrival order
    assert greedy.next_item().payload == "d1"    # big first
    assert asl.next_item().payload == "d1"       # little is standby
    # window expiry promotes the standby ahead of later big work
    clk["t"] = 11.0
    asl.submit("d2", "big")
    assert asl.next_item().payload == "p1"
    assert asl.next_item().payload == "d2"


def test_asl_work_conserving_when_idle():
    clk = {"t": 0.0}
    asl = ASLScheduler(lambda: clk["t"], default_window=100.0)
    asl.submit("p1", "little")
    assert asl.next_item().payload == "p1"   # no big work: admit at once


def test_asl_promotion_expiry_order():
    """Standbys promote to FIFO in (deadline, seq) order, not arrival order
    (regression for the heapq refactor: each standby has its own window)."""
    clk = {"t": 0.0}
    asl = ASLScheduler(lambda: clk["t"], default_window=10.0,
                       max_window=100.0)
    asl.submit("slow", "little", epoch_id=0)     # window 10 -> deadline 10
    asl.observe_epoch(1, latency=50.0, slo=1.0)  # epoch 1 window halves
    clk["t"] = 2.0
    asl.submit("fast", "little", epoch_id=1)     # shorter window, later
    # arrival but earlier deadline
    assert asl._standby[0][2].payload == "fast"
    clk["t"] = 50.0                              # both expired
    asl.submit("d", "big")
    got = [asl.next_item().payload for _ in range(3)]
    assert got == ["fast", "slow", "d"]          # expiry order, then big
    assert asl.pending() == 0


def test_asl_feedback_shrinks_window_on_violation():
    asl = ASLScheduler(lambda: 0.0, default_window=1.0, max_window=10.0)
    w0 = asl.window(0)
    asl.observe_epoch(0, latency=5.0, slo=1.0)
    assert asl.window(0) < w0
    for _ in range(10):
        asl.observe_epoch(0, latency=0.1, slo=1.0)
    assert asl.window(0) > asl.window(0) * 0.0  # grew linearly, capped
    assert asl.window(0) <= 10.0


def test_straggler_draws_counter_pure():
    """The straggler sim is off np.random: draws are pure in
    (seed, pod, step) — identical runs repeat bit-exactly, the pattern
    survives a horizon change (prefix invariance), and pods' streams
    are independent of the pod count."""
    from repro.workloads.generators import straggle_uniforms
    kw = dict(straggle_prob=0.2, straggle_factor=4.0, seed=7)
    mk = lambda: BoundedStalenessController(4, window_steps=3.0,
                                            max_window=6.0)
    a = simulate(4, [1.0] * 4, controller=mk(), horizon_steps=120, **kw)
    b = simulate(4, [1.0] * 4, controller=mk(), horizon_steps=120, **kw)
    assert a == b
    # prefix invariance: draw i of pod p does not depend on the horizon
    np.testing.assert_array_equal(straggle_uniforms(7, 2, 50),
                                  straggle_uniforms(7, 2, 500)[:50])
    # pod streams are namespaced (not one shared sequence)
    assert not np.array_equal(straggle_uniforms(7, 0, 50),
                              straggle_uniforms(7, 1, 50))
