"""Test-session bootstrap.

Virtualizes 8 host-platform devices *before the first jax import* so the
multi-device paths — mesh-sharded sweeps (``simlock.sweep(mesh=...)``),
sweep sharding rules, sub-production dry-run cells — run for real in CI
on this CPU-only container.  Unsharded computations still place on device
0 only, so single-device tests are unaffected.
"""

import sys
from pathlib import Path

# Make `repro` importable even without PYTHONPATH=src (and for this
# bootstrap itself, which must run before any jax import).
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.xla_flags import ensure_host_devices  # noqa: E402

ensure_host_devices(8)
