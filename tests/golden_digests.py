"""Golden sha256 digests of the simulator's outputs — the bit-parity
harness for engine refactors.

A refactor that claims "default runs are bit-identical" must prove it
against digests captured from the PRE-refactor engine: run this module
as a script AT THE OLD COMMIT to (re)generate
``tests/data/keyshard_golden.json``, land the JSON with the refactor,
and let ``tests/test_keyshard.py::test_pre_refactor_digest_parity``
replay the same configs on the new engine and compare field-by-field.

    PYTHONPATH=src python tests/golden_digests.py

The capture deliberately uses only the stable public API (``SimConfig``
/ ``run`` / ``sweep`` / ``summarize``) so the script itself is valid on
both sides of the refactor.  Digests cover every state field by NAME
(``pol.*`` entries keyed individually): a refactor may ADD fields — the
parity test only checks the fields the golden file names.
"""

from __future__ import annotations

import hashlib
import json
import pathlib
import sys

import numpy as np

GOLDEN = pathlib.Path(__file__).parent / "data" / "keyshard_golden.json"

#: Per-policy run/sweep shapes.  Small horizons (the digest only needs
#: every code path exercised, not converged statistics) but big enough
#: that queues form and windows adapt.
SIM_US = 4_000.0
SLO_US = 80.0
SEED = 3
SWEEP_AXES = {"slo_us": [40.0, 90.0], "n_cores": [4, 8]}


def _sha(x) -> str:
    a = np.ascontiguousarray(np.asarray(x))
    return hashlib.sha256(a.tobytes()).hexdigest()


def digest_state(st) -> dict:
    """name -> sha256 of the raw bytes, SimState.pol keyed per-entry."""
    out = {}
    for name, val in st._asdict().items():
        if name == "pol":
            for k in sorted(val):
                out[f"pol.{k}"] = _sha(val[k])
        else:
            out[name] = _sha(val)
    return out


def digest_summary(summary: dict) -> str:
    return hashlib.sha256(
        json.dumps(summary, sort_keys=True).encode()).hexdigest()


def capture_policy(name: str) -> dict:
    """Digest single / sweep / summary outputs for ONE registered
    policy, plus a stochastic closed-loop and an open-loop variant."""
    from repro.core import simlock as sl

    cfg = sl.SimConfig(policy=name, sim_time_us=SIM_US)
    st = sl.run(cfg, SLO_US, seed=SEED)
    rec = {"single": digest_state(st),
           "summary": digest_summary(
               sl.summarize(cfg, st, slo_us=SLO_US))}
    st_sw, _ = sl.sweep(cfg, dict(SWEEP_AXES), slo_us=SLO_US,
                        seed=SEED)
    rec["sweep"] = digest_state(st_sw)
    wl_cfg = sl.SimConfig(policy=name, wl=True, wl_process="poisson",
                          wl_service="lognormal", wl_cv=1.5,
                          sim_time_us=SIM_US)
    rec["wl_single"] = digest_state(sl.run(wl_cfg, SLO_US, seed=SEED))
    op_cfg = sl.SimConfig(policy=name, wl=True, wl_open=True,
                          wl_process="poisson", wl_rate=0.8,
                          sim_time_us=SIM_US)
    rec["open_single"] = digest_state(sl.run(op_cfg, SLO_US, seed=SEED))
    return rec


def capture() -> dict:
    """:func:`capture_policy` for every registered policy."""
    from repro.core.policies import REGISTRY

    return {name: capture_policy(name) for name in REGISTRY}


def main():
    GOLDEN.parent.mkdir(parents=True, exist_ok=True)
    GOLDEN.write_text(json.dumps(capture(), indent=1, sort_keys=True))
    print(f"wrote {GOLDEN}")


if __name__ == "__main__":
    sys.exit(main())
