"""Reproduce the paper's core figures on the JAX discrete-event simulator.

    PYTHONPATH=src python examples/lock_microbench.py

Prints the full policy matrix (every policy registered in
repro.core.policies — new plugins appear here automatically),
Figure-1-style scaling (MCS collapse, TAS latency collapse) and the
Figure-8b SLO sweep (LibASL throughput grows with the SLO while the
little-core P99 tracks the SLO line).
"""

import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT / "src"))
sys.path.insert(0, str(_ROOT))          # benchmarks.* (loadlat helper)

import jax                                  # noqa: E402
import numpy as np                          # noqa: E402

from repro.core import energy               # noqa: E402
from repro.core import simlock as sl        # noqa: E402
from repro.core.policies import REGISTRY    # noqa: E402


def policy_matrix(slo_us=100.0, sim_time_us=20_000.0):
    """One row per *registered* lock policy, same 4+4 AMP workload —
    a new policy plugin shows up here (and in the CI probe) for free.
    The energy columns use the calibrated big.LITTLE power tables
    (repro.core.energy, docs/energy.md): J burnt over the run,
    throughput-per-watt and the energy-delay product."""
    print(f"== Policy matrix: {len(REGISTRY)} registered policies "
          f"(SLO {slo_us:.0f}us) ==")
    print(f"{'policy':>8} {'tput':>9} {'little p99':>11} {'big p99':>9} "
          f"{'little share':>13} {'J':>7} {'tput/W':>8} {'EDP':>9}")
    for name in REGISTRY:
        cfg = sl.SimConfig(policy=name, sim_time_us=sim_time_us)
        cfg = sl.with_columns(cfg, **energy.amp_power(cfg.big))
        s = sl.summarize(cfg, sl.run(cfg, slo_us))
        cs = np.asarray(s["cs_per_core"], float)
        share = cs[4:].sum() / max(cs.sum(), 1.0)
        print(f"{name:>8} {s['throughput_cs_per_s']:>9.0f} "
              f"{s['ep_p99_little_us']:>10.1f}u "
              f"{s['ep_p99_big_us']:>8.1f}u {share:>12.0%} "
              f"{s['energy_j']:>7.4f} {s['tput_per_watt']:>8.0f} "
              f"{s['edp']:>9.2e}")


def figure1(ns=range(1, 9), sim_time_us=40_000.0):
    print("== Figure 1: scaling 1..8 threads (4 big + 4 little) ==")
    print(f"{'n':>2} {'MCS tput':>10} {'MCS p99':>9} {'TAS tput':>10} "
          f"{'TAS p99':>9}")
    for n in ns:
        big = tuple([1] * min(n, 4) + [0] * max(n - 4, 0))
        kw = dict(n_cores=n, big=big,
                  speed_cs=tuple(1.0 if b else 3.75 for b in big),
                  speed_nc=tuple(1.0 if b else 1.8 for b in big),
                  sim_time_us=sim_time_us)
        mcs_cfg = sl.SimConfig(policy="fifo", **kw)
        mcs = sl.summarize(mcs_cfg, sl.run(mcs_cfg, 1e9))
        tas_cfg = sl.SimConfig(policy="tas", w_big=0.15, **kw)
        tas = sl.summarize(tas_cfg, sl.run(tas_cfg, 1e9))
        print(f"{n:>2} {mcs['throughput_cs_per_s']:>10.0f} "
              f"{mcs['cs_p99_all_us']:>8.1f}u "
              f"{tas['throughput_cs_per_s']:>10.0f} "
              f"{tas['cs_p99_all_us']:>8.1f}u")


def figure8b(slos=(20., 40., 60., 80., 100., 150., 200.),
             sim_time_us=50_000.0):
    print("\n== Figure 8b: LibASL SLO sweep (one jax.vmap) ==")
    cfg = sl.SimConfig(policy="libasl", sim_time_us=sim_time_us)
    st = sl.sweep_slo(cfg, list(slos))
    print(f"{'SLO us':>7} {'tput':>9} {'little p99':>11} {'big p99':>9}")
    for i, slo in enumerate(slos):
        s = sl.summarize(cfg, jax.tree.map(lambda x: x[i], st))
        print(f"{slo:>7.0f} {s['throughput_cs_per_s']:>9.0f} "
              f"{s['ep_p99_little_us']:>10.1f}u "
              f"{s['ep_p99_big_us']:>8.1f}u")


def loadlat(fracs=(0.4, 0.9, 3.0), sim_time_us=20_000.0):
    print("\n== Load-latency: stochastic workload (repro.workloads) ==")
    from benchmarks.paper_figs import _loadlat_rate
    rates = [_loadlat_rate(f) for f in fracs]

    def curve(policy, slo_us):
        cfg = sl.SimConfig(policy=policy, wl=True, wl_process="poisson",
                           wl_service="lognormal", wl_cv=1.0,
                           sim_time_us=sim_time_us)
        st, _ = sl.sweep(cfg, {"arrival_rate": rates}, slo_us=slo_us)
        return [sl.summarize(cfg, jax.tree.map(lambda x, i=i: x[i], st))
                for i in range(len(rates))]

    mcs = curve("fifo", 1e9)
    asl = curve("libasl", 200.0)
    print(f"{'load':>5} {'MCS tput':>10} {'MCS p99':>9} "
          f"{'ASL tput':>10} {'ASL p99':>9}")
    for f, m, a in zip(fracs, mcs, asl):
        print(f"{f:>5.1f} {m['throughput_cs_per_s']:>10.0f} "
              f"{m['ep_p99_little_us']:>8.1f}u "
              f"{a['throughput_cs_per_s']:>10.0f} "
              f"{a['ep_p99_little_us']:>8.1f}u")


def openloop(fracs=(0.4, 0.9, 1.1), sim_time_us=20_000.0):
    print("\n== Open-loop arrivals (wl_open: arrivals as events) ==")
    from benchmarks.paper_figs import _openloop_rate
    rates = [_openloop_rate(f) for f in fracs]
    cfg = sl.SimConfig(policy="libasl", wl=True, wl_open=True,
                       wl_process="poisson", sim_time_us=sim_time_us)
    st, _ = sl.sweep(cfg, {"arrival_rate": rates}, slo_us=300.0)
    print(f"{'load':>5} {'tput':>9} {'sojourn p99':>12}")
    for i, f in enumerate(fracs):
        s = sl.summarize(cfg, jax.tree.map(lambda x, i=i: x[i], st))
        print(f"{f:>5.1f} {s['throughput_cs_per_s']:>9.0f} "
              f"{s['ep_p99_all_us']:>11.1f}u")


def keyshard_matrix(locks=8, zipf=0.99, n_keys=1024,
                    sim_time_us=20_000.0):
    """Registry-driven key-sharded matrix (--locks / --zipf): every
    registered policy on the same Zipf-keyed multi-lock workload
    (docs/workloads.md §Key-sharded traffic).  The key-affinity
    policies (ks_*) separate from the CRCW baseline (plain fifo) as the
    traffic gets hotter (--zipf up) or the buckets fewer (--locks
    down)."""
    print(f"\n== Key-sharded matrix: {len(REGISTRY)} policies x "
          f"{locks} locks, Zipf theta={zipf:g} over {n_keys} keys ==")
    print(f"{'policy':>9} {'tput':>9} {'ep p99':>9} {'little p99':>11}")
    for name in REGISTRY:
        cfg = sl.SimConfig(policy=name, sim_time_us=sim_time_us,
                           n_locks=locks, n_keys=n_keys,
                           zipf_theta=zipf)
        s = sl.summarize(cfg, sl.run(cfg, 100.0))
        print(f"{name:>9} {s['throughput_cs_per_s']:>9.0f} "
              f"{s['ep_p99_all_us']:>8.1f}u "
              f"{s['ep_p99_little_us']:>10.1f}u")


def main(ns=range(1, 9), slos=(20., 40., 60., 80., 100., 150., 200.),
         sim_time_us=40_000.0, fracs=(0.4, 0.9, 3.0), locks=8,
         zipf=0.99):
    policy_matrix(sim_time_us=sim_time_us / 2)
    keyshard_matrix(locks, zipf, sim_time_us=sim_time_us / 2)
    figure1(ns, sim_time_us)
    figure8b(slos, sim_time_us)
    loadlat(fracs, sim_time_us=sim_time_us / 2)
    openloop(sim_time_us=sim_time_us / 2)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(
        description="Paper-figure lock microbenchmarks")
    ap.add_argument("--locks", type=int, default=8,
                    help="bucket-lock count of the key-sharded matrix")
    ap.add_argument("--zipf", type=float, default=0.99,
                    help="Zipf exponent of the key-sharded matrix "
                         "(0 = uniform, >1 = hot-key collapse)")
    args = ap.parse_args()
    main(locks=args.locks, zipf=args.zipf)
