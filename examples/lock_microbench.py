"""Reproduce the paper's core figures on the JAX discrete-event simulator.

    PYTHONPATH=src python examples/lock_microbench.py

Prints Figure-1-style scaling (MCS collapse, TAS latency collapse) and the
Figure-8b SLO sweep (LibASL throughput grows with the SLO while the little-
core P99 tracks the SLO line).
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax                                  # noqa: E402
import numpy as np                          # noqa: E402

from repro.core import simlock as sl        # noqa: E402


def figure1():
    print("== Figure 1: scaling 1..8 threads (4 big + 4 little) ==")
    print(f"{'n':>2} {'MCS tput':>10} {'MCS p99':>9} {'TAS tput':>10} "
          f"{'TAS p99':>9}")
    for n in range(1, 9):
        big = tuple([1] * min(n, 4) + [0] * max(n - 4, 0))
        kw = dict(n_cores=n, big=big,
                  speed_cs=tuple(1.0 if b else 3.75 for b in big),
                  speed_nc=tuple(1.0 if b else 1.8 for b in big),
                  sim_time_us=40_000.0)
        mcs_cfg = sl.SimConfig(policy="fifo", **kw)
        mcs = sl.summarize(mcs_cfg, sl.run(mcs_cfg, 1e9))
        tas_cfg = sl.SimConfig(policy="tas", w_big=0.15, **kw)
        tas = sl.summarize(tas_cfg, sl.run(tas_cfg, 1e9))
        print(f"{n:>2} {mcs['throughput_cs_per_s']:>10.0f} "
              f"{mcs['cs_p99_all_us']:>8.1f}u "
              f"{tas['throughput_cs_per_s']:>10.0f} "
              f"{tas['cs_p99_all_us']:>8.1f}u")


def figure8b():
    print("\n== Figure 8b: LibASL SLO sweep (one jax.vmap) ==")
    cfg = sl.SimConfig(policy="libasl", sim_time_us=50_000.0)
    slos = [20., 40., 60., 80., 100., 150., 200.]
    st = sl.sweep_slo(cfg, slos)
    print(f"{'SLO us':>7} {'tput':>9} {'little p99':>11} {'big p99':>9}")
    for i, slo in enumerate(slos):
        s = sl.summarize(cfg, jax.tree.map(lambda x: x[i], st))
        print(f"{slo:>7.0f} {s['throughput_cs_per_s']:>9.0f} "
              f"{s['ep_p99_little_us']:>10.1f}u "
              f"{s['ep_p99_big_us']:>8.1f}u")


def main():
    figure1()
    figure8b()


if __name__ == "__main__":
    main()
