"""Bounded-staleness straggler mitigation — the paper's lock ordering
applied to gradient commits.

    PYTHONPATH=src python examples/straggler_training.py

Simulates an 8-pod data-parallel job with transient stragglers (10% of
steps take 5x: preemptions, ECC retries, network blips) and compares
synchronous training, unbounded async, and the AIMD-windowed policy.
Then runs a REAL 2-worker demonstration: two Trainer instances sharing a
BoundedStalenessController, one artificially slowed.
"""

import pathlib
import sys
import tempfile
import threading
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs import registry                              # noqa: E402
from repro.dist.staleness import (BoundedStalenessController,   # noqa: E402
                                  simulate)
from repro.train.trainer import Trainer, TrainerConfig          # noqa: E402


def main():
    print("== simulation: 8 pods, 10% of steps straggle 5x ==")
    kw = dict(straggle_prob=0.1, straggle_factor=5.0, seed=11,
              horizon_steps=300)
    for name, ctl, extra in (
            ("synchronous", BoundedStalenessController(
                8, window_steps=0.0, max_window=0.0), {}),
            ("unbounded-async", BoundedStalenessController(
                8, window_steps=1e6, max_window=1e6),
             dict(quality_slo=float("inf"))),
            ("asl-window(AIMD)", BoundedStalenessController(
                8, window_steps=4.0, max_window=8.0),
             dict(quality_slo=6.0, penalty_per_stale=1.0))):
        sps, mean_st, p99_st = simulate(8, [1.0] * 8, controller=ctl,
                                        **kw, **extra)
        print(f"  {name:18s} steps/s={sps:6.2f}  staleness "
              f"mean={mean_st:4.1f} p99={p99_st:4.0f}")

    print("\n== live demo: 2 trainers, one slowed, shared window ==")
    cfg = registry.get_tiny("gemma_7b")
    ctl = BoundedStalenessController(2, window_steps=2.0, max_window=4.0)
    results = {}

    def worker(pod, slow):
        with tempfile.TemporaryDirectory() as d:
            t = Trainer(cfg, TrainerConfig(
                total_steps=12, ckpt_every=100, ckpt_dir=d,
                global_batch=4, seq_len=32, seed=pod))
            params, opt_state, step = t.init_or_restore()
            import jax
            step_j = jax.numpy.int32(0)
            while step < 12:
                while not ctl.can_commit(pod):
                    time.sleep(0.005)
                if slow:
                    time.sleep(0.05)
                batch = t.data.batch(step)
                params, opt_state, step_j, m = t.step_fn(
                    params, opt_state, step_j,
                    jax.tree.map(jax.numpy.asarray, batch))
                step += 1
                ctl.commit(pod)
            results[pod] = float(m["loss"])

    ts = [threading.Thread(target=worker, args=(0, False)),
          threading.Thread(target=worker, args=(1, True))]
    t0 = time.time()
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    print(f"  both pods finished 12 steps in {time.time()-t0:.1f}s, "
          f"staleness stayed <= {ctl.window}; losses {results}")


if __name__ == "__main__":
    main()
