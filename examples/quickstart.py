"""Quickstart: train a small LM end-to-end with checkpoint/restart.

    PYTHONPATH=src python examples/quickstart.py

Trains a reduced yi-6b-family config on the synthetic Markov stream for 60
steps (loss drops from ~ln(vocab) toward the stream's conditional entropy),
simulates a preemption at step 30, restarts from the checkpoint, and
verifies the resumed run continues exactly.
"""

import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs import registry                      # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig  # noqa: E402


def main(total_steps=60, preempt_at=30, ckpt_every=10, global_batch=8,
         seq_len=64, lr=3e-3, check_loss=True):
    """Parameterized so the test suite can smoke-run it with tiny
    arguments (tests/test_examples.py); defaults reproduce the demo."""
    cfg = registry.get_tiny("yi_6b")
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(total_steps=total_steps,
                             ckpt_every=ckpt_every, ckpt_dir=d,
                             lr=lr, global_batch=global_batch,
                             seq_len=seq_len)

        print(f"== phase 1: train {preempt_at} steps, then 'preempt' ==")
        t1 = Trainer(cfg, tcfg)
        out1 = t1.run(max_steps=preempt_at)
        print(f"   step={out1['step']} "
              f"loss {out1['history'][0]['loss']:.3f} -> "
              f"{out1['history'][-1]['loss']:.3f}")

        print("== phase 2: fresh process restores from checkpoint ==")
        t2 = Trainer(cfg, tcfg)
        assert t2.ckpt.latest() == preempt_at
        out2 = t2.run()
        print(f"   resumed at {preempt_at}, finished at "
              f"step={out2['step']} "
              f"final loss {out2['history'][-1]['loss']:.3f}")
        assert out2["step"] == total_steps
        if check_loss:
            assert out2["history"][-1]["loss"] < out1["history"][0]["loss"]
        print("quickstart OK: loss decreased and restart was seamless")
        return out2


if __name__ == "__main__":
    main()
