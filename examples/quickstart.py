"""Quickstart: train a small LM end-to-end with checkpoint/restart.

    PYTHONPATH=src python examples/quickstart.py

Trains a reduced yi-6b-family config on the synthetic Markov stream for 60
steps (loss drops from ~ln(vocab) toward the stream's conditional entropy),
simulates a preemption at step 30, restarts from the checkpoint, and
verifies the resumed run continues exactly.
"""

import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs import registry                      # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig  # noqa: E402


def main():
    cfg = registry.get_tiny("yi_6b")
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(total_steps=60, ckpt_every=10, ckpt_dir=d,
                             lr=3e-3, global_batch=8, seq_len=64)

        print("== phase 1: train 30 steps, then 'preempt' ==")
        t1 = Trainer(cfg, tcfg)
        out1 = t1.run(max_steps=30)
        print(f"   step={out1['step']} "
              f"loss {out1['history'][0]['loss']:.3f} -> "
              f"{out1['history'][-1]['loss']:.3f}")

        print("== phase 2: fresh process restores from checkpoint ==")
        t2 = Trainer(cfg, tcfg)
        assert t2.ckpt.latest() == 30
        out2 = t2.run()
        print(f"   resumed at 30, finished at step={out2['step']} "
              f"final loss {out2['history'][-1]['loss']:.3f}")
        assert out2["step"] == 60
        assert out2["history"][-1]["loss"] < out1["history"][0]["loss"]
        print("quickstart OK: loss decreased and restart was seamless")


if __name__ == "__main__":
    main()
