"""Serve a real (tiny) model under load with SLO-guided admission.

    PYTHONPATH=src python examples/serve_slo.py

Calibrates the engine cost model from *measured* jitted prefill/decode
steps of a reduced llava-family config, then drives identical Poisson
workloads through FIFO / greedy / ASL admission and prints the
throughput-vs-TTFT trade — the paper's Figure 2 usage model end-to-end.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import calibrated_cost          # noqa: E402
from repro.configs import registry                      # noqa: E402
from repro.serving.engine import (ServingEngine,        # noqa: E402
                                  poisson_workload)


def main():
    cfg = registry.get_tiny("yi_6b")
    cost = calibrated_cost(cfg, batch=4, prefill_chunk=128, t_cache=256)
    print(f"calibrated on {cfg.name}: decode={cost.decode_step_s*1e3:.2f}ms"
          f"  prefill_chunk={cost.prefill_chunk_s*1e3:.2f}ms")

    # Target ~50% prefill utilization: rate * avg_chunks * chunk_cost = 0.5
    avg_chunks = (256 + 512 + 1024) / 3 / cost.prefill_chunk
    rate = 0.5 / (avg_chunks * cost.prefill_chunk_s)
    slo = 14 * cost.prefill_chunk_s
    print(f"workload: poisson {rate:.1f} rps, TTFT SLO {slo*1e3:.0f}ms")
    print(f"{'sched':>8} {'n':>5} {'tok/s':>8} {'ttft_p99':>9} "
          f"{'itl_p99':>8} {'viol':>6}")
    for sched in ("fifo", "greedy", "asl"):
        kw = {"default_window": slo / 10, "max_window": 50 * slo} \
            if sched == "asl" else {}
        eng = ServingEngine(sched, cost, scheduler_kwargs=kw, seed=0)
        poisson_workload(eng, rate_rps=rate, duration_s=600 * slo,
                         prompt_lens=[256, 512, 1024],
                         new_tokens=[16, 64], slo_ttft=slo, seed=1)
        m = eng.metrics()
        print(f"{sched:>8} {m['n']:>5} {m['throughput_tok_s']:>8.0f} "
              f"{m['ttft_p99']*1e3:>8.0f}m {m['itl_p99']*1e3:>7.1f}m "
              f"{m['slo_violation_rate']:>6.1%}")


if __name__ == "__main__":
    main()
